"""Discrete-event cluster simulator: arrivals → router → replicas.

The event loop advances a global clock over three event kinds: request
arrivals (from the open-loop process), replica step completions, and —
when a :class:`repro.faults.FaultInjector` is attached — scripted fault
actions plus their (delayed) detections.  A replica runs engine steps
back-to-back while it has work; each step's duration comes from the
per-step cost model given the batch it actually contains at step start —
the standard trace-driven serving-simulator structure (NeuPIMs lineage).

Fault semantics (repro.faults):

* **replica crash** — the replica aborts its in-flight step immediately;
  the control plane only notices after ``detect_latency`` (a heartbeat-
  timeout model), at which point the router excludes the replica and
  every orphaned request (in-flight at the crash, or routed to the corpse
  during the detection window) is recovered one of two ways.  With
  ``migrate_kv`` the orphan's KV pages are *warm-migrated* to the
  surviving replica with the most headroom — progress is preserved, and
  the page transfer is charged through the interconnect model
  (``p2p_time`` over the request's KV bytes) before the request lands on
  the target's queue.  Without it (or when no replica has headroom) the
  orphan is *cold re-dispatched*: progress reset, then re-routed after a
  seeded jittered-exponential backoff (crash storms must not synchronize
  retries), up to ``max_retries`` times; beyond that it is counted
  dropped.  Every recovery decision (detection, migration target, backoff
  draw, drop) is journaled — see :class:`repro.recovery.RecoveryJournal`
  — so a seeded chaos run replays bit-identically.  On the fault's clear
  the replica rejoins the rotation.
* **pim brownout / link degrade / straggle** — the replica keeps serving,
  slower; the :class:`HealthMonitor` watches per-replica step durations
  (EMA + spike detection) and flags sustained inflation DEGRADED, which
  deprioritizes the replica in the router until the duration signal
  recovers.
* **load shedding / admission control** — with ``shed_delay`` set the
  router refuses arrivals whose estimated queueing delay exceeds the
  bound (see :class:`Router`); with an :class:`AdmissionConfig` the full
  overload layer engages (per-class token buckets, EDF bounded queues
  with loud deadline expiry, retry budget + circuit breaker on the
  re-dispatch path, staged brownout) — see
  :mod:`repro.cluster.admission`.

Request conservation generalizes under faults and overload: every
submitted request leaves exactly one explicit outcome —
``completed + shed + expired + dropped == submitted`` — asserted after
every run and pinned by the chaos and admission tests.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.cost_model import SystemSpec
from repro.faults.health import DEGRADED, HealthMonitor, Transition
from repro.faults.inject import FaultInjector
from repro.faults.plan import (
    LINK_DEGRADE,
    PIM_BROWNOUT,
    REPLICA_CRASH,
    STRAGGLE,
    FaultEvent,
)
from repro.recovery import journal as jrn
from repro.recovery.journal import RecoveryJournal
from repro.sim.engine import BatchState
from repro.sim.interconnect import InterconnectModel
from repro.sim.models import SimModelConfig
from .admission import INTERACTIVE, AdmissionConfig, AdmissionController
from .arrivals import ArrivalProcess, RequestSpec
from .metrics import SLO, summarize
from .replica import ClusterRequest, Replica, ReplicaConfig
from .router import Router

_EPS = 1e-12


@dataclass
class ClusterResult:
    completed: List[ClusterRequest]
    horizon: float
    end_time: float  # when the last request finished (drain included)
    replicas: List[Replica]
    n_submitted: int
    # requests that did not complete, by explicit outcome:
    # dropped — crash recovery exhausted (retries past the budget)
    # shed — refused at admission (rate limit, bounded queues, delay
    #        bound, brownout, pool down), each with a shed_reason
    # expired — deadline passed before service start (queued or awaiting
    #           re-dispatch), stamped with expire_time
    dropped: List[ClusterRequest] = field(default_factory=list)
    shed: List[ClusterRequest] = field(default_factory=list)
    expired: List[ClusterRequest] = field(default_factory=list)
    shed_reasons: Dict[str, int] = field(default_factory=dict)
    # applied fault actions (t, phase, kind, target, magnitude) and the
    # health transitions observed — the chaos determinism tests compare
    # these across same-seed runs
    fault_log: List[Tuple[float, str, str, int, float]] = field(
        default_factory=list
    )
    transitions: List[Transition] = field(default_factory=list)
    n_shed: int = 0
    # recovery accounting: warm KV migrations vs cold (progress-reset)
    # re-dispatches, and the journal of every recovery decision
    n_migrations: int = 0
    n_cold_redispatch: int = 0
    journal: Optional[RecoveryJournal] = None
    # admission-layer summary (brownout transitions, breaker, retry
    # budget); None when the run had no AdmissionController
    admission: Optional[Dict] = None

    @property
    def n_expired(self) -> int:
        return len(self.expired)

    def report(self, slo: Optional[SLO] = None) -> Dict:
        return summarize(
            self.completed,
            self.horizon,
            slo=slo,
            replicas=self.replicas,
            end_time=self.end_time,
            dropped=self.dropped,
            shed=self.shed,
            expired=self.expired,
            shed_reasons=self.shed_reasons,
            recovery={
                "n_migrations": self.n_migrations,
                "n_cold_redispatch": self.n_cold_redispatch,
                "n_journal_entries": (
                    len(self.journal) if self.journal is not None else 0
                ),
            },
            admission=self.admission,
        )


class ClusterSimulator:
    """N identical replicas behind one router, fed by an arrival process.

    ``detect_latency`` models the heartbeat timeout between a replica
    crash and the control plane acting on it; ``max_retries`` bounds
    crash re-dispatches per request; ``shed_delay`` enables admission
    control (see :class:`Router`); ``health`` supplies a configured
    :class:`HealthMonitor` (a default is built when faults are injected).

    ``migrate_kv`` turns on warm KV migration: crash orphans with progress
    keep it by shipping their KV pages (``n_layers x kv_bytes(1, pos)``
    over the interconnect's ``p2p_time``) to the surviving replica with
    the most headroom, falling back to cold re-dispatch when none has
    any.  ``backoff_base`` scales the cold path's jittered exponential
    retry delay (``base * 2^(retries-1) * U[0.5, 1.5)``, seeded off
    ``seed`` — deterministic, and desynchronized across a crash storm).
    """

    def __init__(
        self,
        model: SimModelConfig,
        system: SystemSpec,
        policy: str = "sieve",
        n_replicas: int = 1,
        router_policy: str = "round_robin",
        replica_cfg: Optional[ReplicaConfig] = None,
        seed: int = 0,
        telemetry=None,
        detect_latency: float = 0.05,
        max_retries: int = 3,
        shed_delay: Optional[float] = None,
        health: Optional[HealthMonitor] = None,
        migrate_kv: bool = False,
        backoff_base: float = 0.02,
        admission: Optional[AdmissionConfig] = None,
    ):
        # one Telemetry instance spans all replicas: each replica records
        # onto its own ``replica-{i}`` track in simulated time, so a run
        # exports as a single Perfetto timeline across the cluster
        self.replicas = [
            Replica(
                i, model, system, policy,
                cfg=replica_cfg, seed=seed, telemetry=telemetry,
            )
            for i in range(n_replicas)
        ]
        self.tel = telemetry
        self.detect_latency = detect_latency
        self.max_retries = max_retries
        self.shed_delay = shed_delay
        self.migrate_kv = migrate_kv
        self.backoff_base = backoff_base
        self._seed = seed
        self._model = model
        self.interconnect = InterconnectModel(
            system.xpu, n_gpus=max(model.n_gpus, 1)
        )
        self.health = health or HealthMonitor(
            threshold=2.5, alpha=0.2, warmup=3, confirm=2, recover=2,
            telemetry=telemetry,
        )
        self.router = Router(router_policy, self.replicas, shed_delay=shed_delay)
        # overload-robustness layer (repro.cluster.admission): per-class
        # token buckets, retry budget, circuit breaker, staged brownout.
        # None keeps the pre-admission behavior bit-identical.
        self.admission = (
            AdmissionController(admission, telemetry=telemetry)
            if admission is not None
            else None
        )

    def set_router(self, router_policy: str) -> None:
        """Swap the routing policy while keeping the replicas (and their
        warmed cost tables + step-duration caches).  Sweeps over routers
        reuse one cluster instead of re-paying warmup per router."""
        self.router = Router(
            router_policy, self.replicas, shed_delay=self.shed_delay
        )

    def run(
        self,
        arrivals: ArrivalProcess,
        horizon: float,
        max_steps: int = 2_000_000,
        injector: Optional[FaultInjector] = None,
        journal: Optional[RecoveryJournal] = None,
    ) -> ClusterResult:
        specs: List[RequestSpec] = arrivals.generate(horizon)
        return self.run_requests(
            specs, horizon, max_steps=max_steps, injector=injector,
            journal=journal,
        )

    # ---- fault application ----------------------------------------------
    def _apply_fault(
        self,
        phase: str,
        ev: FaultEvent,
        now: float,
        detections: List[Tuple[float, int]],
    ) -> None:
        rep = self.replicas[ev.target % len(self.replicas)]
        starting = phase == "start"
        if ev.kind == REPLICA_CRASH:
            if starting:
                orphans = rep.fail(now)
                # in-flight work is lost *now*; the control plane acts at
                # detection time (heartbeat timeout)
                detections.append((now + self.detect_latency, rep.replica_id))
                self._orphans.extend(orphans)
            else:
                rep.recover(now)
                self.router.include(rep.replica_id)
                self.health.mark_recovered(
                    f"replica-{rep.replica_id}", t=now, reason="crash cleared"
                )
        elif ev.kind == PIM_BROWNOUT:
            rep.set_pim_degrade(ev.magnitude if starting else 1.0)
        elif ev.kind == LINK_DEGRADE:
            rep.set_link_degrade(ev.magnitude if starting else 1.0)
        elif ev.kind == STRAGGLE:
            rep.set_straggle(ev.magnitude if starting else 1.0)

    # ---- crash recovery --------------------------------------------------
    def _handoff_time(self, req: ClusterRequest) -> float:
        """Interconnect cost of shipping one orphan's KV pages: a p2p
        transfer of its per-layer KV footprint at its current position."""
        m = self._model
        return self.interconnect.p2p_time(
            m.n_layers * m.attn.kv_bytes(1, max(req.position, 1))
        )

    def _pick_migration_target(self, req: ClusterRequest) -> Optional[int]:
        """Surviving replica with headroom and the least committed KV, or
        None (cold fallback).  Deterministic tie-break by replica id."""
        best = None
        for rep in self.replicas:
            if rep.failed or rep.replica_id in self.router.excluded:
                continue
            if rep.queue_len >= rep.cfg.n_slots:
                continue  # no headroom: would just queue behind a full pool
            key = (rep.kv_load, rep.replica_id)
            if best is None or key < best[0]:
                best = (key, rep.replica_id)
        return None if best is None else best[1]

    def _handle_orphans(
        self,
        orphans: List[ClusterRequest],
        now: float,
        dropped: List[ClusterRequest],
    ) -> None:
        """Recover crash orphans: warm KV migration when possible, else
        cold re-dispatch with jittered exponential backoff (bounded by
        ``max_retries``).  Every decision is journaled; during replay the
        journal *drives* the decisions instead."""
        jr = self.journal
        for req in orphans:
            # Deadline expiry before any recovery work: an orphan whose
            # service-start deadline has passed (and never produced a first
            # token) gets neither a migration nor a retry slot.  The
            # condition is deterministic state, so record() — a
            # passthrough-to-expect during replay — keeps both modes on the
            # same journal sequence.
            if (
                req.deadline is not None
                and req.first_token_time is None
                and req.deadline <= now + _EPS
            ):
                jr.record(now, jrn.EXPIRED, req=req.spec.req_id)
                req.expire_time = now
                req.replica_id = None
                self._expired.append(req)
                continue
            if jr.replaying:
                kind = jr.peek_kind()
                if kind == jrn.MIGRATE:
                    e = jr.expect(now, jrn.MIGRATE, req=req.spec.req_id)
                    self._schedule_migration(
                        req, now, int(e["target"]), float(e["handoff"])
                    )
                    continue
                if kind == jrn.DROP:
                    jr.expect(now, jrn.DROP, req=req.spec.req_id)
                    req.retries += 1
                    dropped.append(req)
                    continue
                e = jr.expect(now, jrn.BACKOFF, req=req.spec.req_id)
                req.retries += 1
                self._schedule_cold_retry(req, now, float(e["delay"]))
                continue

            # live decisions (recorded as they are made)
            target = None
            if (
                self.migrate_kv
                and req.position > 0
                and req.migrations < self.max_retries
            ):
                target = self._pick_migration_target(req)
            if target is not None:
                handoff = self._handoff_time(req)
                jr.record(
                    now, jrn.MIGRATE,
                    req=req.spec.req_id, target=target,
                    handoff=handoff, position=req.position,
                )
                self._schedule_migration(req, now, target, handoff)
                continue
            req.retries += 1
            if req.retries > self.max_retries:
                jr.record(
                    now, jrn.DROP,
                    req=req.spec.req_id, reason="retries_exhausted",
                )
                dropped.append(req)
                continue
            # jittered exponential backoff: deterministic (seeded), and
            # desynchronized — a crash storm's retries spread out instead
            # of hammering the survivors in lockstep
            delay = (
                self.backoff_base
                * (2.0 ** (req.retries - 1))
                * (0.5 + self._backoff_rng.random())
            )
            # retry budget: past the rolling-window cap, the retry is
            # deferred to the window's next free slot (folded into the
            # journaled delay so replay adopts the same schedule)
            adm = self.admission
            if adm is not None and adm.retry_budget is not None:
                grant = adm.retry_budget.acquire_at(now)
                delay = max(delay, grant - now)
            if (
                adm is not None
                and adm.breaker is not None
                and adm.breaker.state != "closed"
            ):
                delay = max(delay, adm.breaker.retry_at(now) - now)
            e = jr.record(
                now, jrn.BACKOFF,
                req=req.spec.req_id, delay=delay, retry=req.retries,
            )
            self._schedule_cold_retry(req, now, float(e["delay"]))

    def _schedule_migration(
        self, req: ClusterRequest, now: float, target: int, handoff: float
    ) -> None:
        req.migrations += 1
        self.n_migrations += 1
        self._migrations.append((now + handoff, req, target))

    def _schedule_cold_retry(
        self, req: ClusterRequest, now: float, delay: float
    ) -> None:
        """Cold path: the KV died unrecovered — progress resets here (the
        replica no longer resets it at fail time; see Replica.fail)."""
        req.prefill_done = 0
        req.generated = 0
        req.admit_time = None
        req.first_token_time = None
        self.n_cold_redispatch += 1
        self._retries.append((now + delay, req))

    def _deliver_recovery_events(
        self, now: float, dropped: List[ClusterRequest]
    ) -> None:
        """Apply due migration arrivals and backoff retries."""
        jr = self.journal
        adm = self.admission
        breaker = adm.breaker if adm is not None else None
        if self._migrations:
            due = [m for m in self._migrations if m[0] <= now + _EPS]
            if due:
                self._migrations = [
                    m for m in self._migrations if m[0] > now + _EPS
                ]
                for _, req, rid in due:
                    rep = self.replicas[rid]
                    if rep.failed or rid in self.router.excluded:
                        # the target died while the pages were in flight:
                        # the pages survive (pool semantics), so the orphan
                        # is re-handled — possibly migrating again
                        self._handle_orphans([req], now, dropped)
                    else:
                        rep.submit(req, now)
                        rep.n_migrated_in += 1
        if self._retries:
            due = [r for r in self._retries if r[0] <= now + _EPS]
            if due:
                self._retries = [r for r in self._retries if r[0] > now + _EPS]
                for _, req in due:
                    # deadline check mirrors _handle_orphans: a retry that
                    # can no longer start in time expires loudly here
                    if (
                        req.deadline is not None
                        and req.first_token_time is None
                        and req.deadline <= now + _EPS
                    ):
                        jr.record(now, jrn.EXPIRED, req=req.spec.req_id)
                        req.expire_time = now
                        self._expired.append(req)
                        continue
                    # circuit breaker on the re-dispatch path: while open
                    # (or half-open with probes spent) the retry is
                    # deferred — NOT dropped and NOT charged a retry — to
                    # the breaker's next probe window.  Bounded: every
                    # cooldown grants fresh half-open probes, and each
                    # failed probe dispatch below burns a real retry.
                    if breaker is not None and not breaker.allow(now):
                        e = jr.record(
                            now, jrn.BACKOFF,
                            req=req.spec.req_id,
                            delay=breaker.retry_at(now) - now,
                            retry=req.retries, reason="breaker",
                        )
                        self._retries.append((now + float(e["delay"]), req))
                        continue
                    if self.router.dispatch(req, now) is not None:
                        if breaker is not None:
                            breaker.on_success(now)
                        continue
                    # dispatch failed (pool down / queues full): charge a
                    # retry and back off again rather than dropping on the
                    # first refusal; past max_retries it drops for real
                    if breaker is not None:
                        breaker.on_failure(now)
                    req.retries += 1
                    if req.retries > self.max_retries:
                        jr.record(
                            now, jrn.DROP,
                            req=req.spec.req_id, reason="no_replica",
                        )
                        dropped.append(req)
                        continue
                    delay = (
                        self.backoff_base
                        * (2.0 ** (req.retries - 1))
                        * (0.5 + self._backoff_rng.random())
                    )
                    if breaker is not None and breaker.state != "closed":
                        delay = max(delay, breaker.retry_at(now) - now)
                    e = jr.record(
                        now, jrn.BACKOFF,
                        req=req.spec.req_id, delay=delay, retry=req.retries,
                    )
                    self._retries.append((now + float(e["delay"]), req))

    def run_requests(
        self,
        specs: List[RequestSpec],
        horizon: float,
        max_steps: int = 2_000_000,
        injector: Optional[FaultInjector] = None,
        journal: Optional[RecoveryJournal] = None,
    ) -> ClusterResult:
        specs = sorted(specs, key=lambda s: s.arrival_time)
        # recovery state (per run): the decision journal (pass a replaying
        # one to re-drive a recorded run), in-flight KV migrations
        # ``(deliver_t, req, target_rid)``, and pending backoff retries
        self.journal = journal if journal is not None else RecoveryJournal()
        self._migrations: List[Tuple[float, ClusterRequest, int]] = []
        self._retries: List[Tuple[float, ClusterRequest]] = []
        self._backoff_rng = np.random.default_rng(self._seed + 0x5EED)
        self.n_migrations = 0
        self.n_cold_redispatch = 0
        self._expired: List[ClusterRequest] = []
        for rep in self.replicas:  # allow back-to-back runs on one cluster
            rep.reset_requests()
        self.router.reset_health()
        adm = self.admission
        if adm is not None:
            adm.reset()
        # queued-deadline expiry only needs event-loop wakeups when some
        # request actually carries a deadline
        deadlines_active = any(s.deadline is not None for s in specs)
        if specs:
            # Batched cost-table warmup on a representative batch state
            # (full decode slots at the trace's mean KV depth + one prefill
            # chunk wave).  One step_time_batch call per replica, before
            # the event loop — and warmup no longer depends on which
            # request happens to arrive first.
            mean_prompt = sum(s.prompt_len for s in specs) / len(specs)
            mean_out = sum(s.output_len for s in specs) / len(specs)
            for rep in self.replicas:
                cfg = rep.cfg
                rep.prewarm(
                    BatchState(
                        n_decode=cfg.n_slots,
                        seq=int(mean_prompt + mean_out / 2),
                        prefill_tokens=cfg.prefill_chunk
                        * cfg.max_prefills_per_step,
                    )
                )
        i = 0
        now = 0.0
        steps = 0
        dropped: List[ClusterRequest] = []
        shed: List[ClusterRequest] = []
        # crash orphans awaiting their detection-time re-dispatch
        self._orphans: List[ClusterRequest] = []
        detections: List[Tuple[float, int]] = []  # (t_detect, replica_id)
        mon = self.health
        while True:
            # next event: earliest of (arrival, step completion, fault
            # action, pending crash detection, queued-request deadline)
            t_next = specs[i].arrival_time if i < len(specs) else None
            for rep in self.replicas:
                if rep.busy_until is not None and (
                    t_next is None or rep.busy_until < t_next
                ):
                    t_next = rep.busy_until
            if injector is not None:
                t_f = injector.next_time()
                if t_f is not None and (t_next is None or t_f < t_next):
                    t_next = t_f
            for t_d, _ in detections:
                if t_next is None or t_d < t_next:
                    t_next = t_d
            for t_m, _, _ in self._migrations:
                if t_next is None or t_m < t_next:
                    t_next = t_m
            for t_r, _ in self._retries:
                if t_next is None or t_r < t_next:
                    t_next = t_r
            if deadlines_active:
                # each queued deadline fires at most once (the sweep below
                # removes the request), so these wakeups cannot loop
                for rep in self.replicas:
                    t_e = rep.next_queue_deadline()
                    if t_e is not None and (t_next is None or t_e < t_next):
                        t_next = t_e
            if t_next is None:
                break  # nothing pending anywhere -> drained
            now = t_next

            if injector is not None:
                for phase, ev in injector.pop_due(now):
                    self._apply_fault(phase, ev, now, detections)
            if detections:
                due = [d for d in detections if d[0] <= now + _EPS]
                if due:
                    detections = [d for d in detections if d[0] > now + _EPS]
                    for _, rid in due:
                        rep = self.replicas[rid]
                        if rep.failed:
                            self.router.exclude(rid)
                            mon.mark_failed(
                                f"replica-{rid}", t=now,
                                reason="heartbeat timeout",
                            )
                            if adm is not None and adm.breaker is not None:
                                # a confirmed crash is a dispatch-path
                                # failure signal; a fully-failed census
                                # force-opens the breaker immediately
                                adm.breaker.on_failure(now)
                                adm.breaker.sync_health(mon, now)
                            # rescue requests routed to the corpse during
                            # the detection window
                            self._orphans.extend(rep.take_queue())
                        # recover everything orphaned (even when the crash
                        # cleared before the control plane noticed — the
                        # in-flight work it killed is still gone)
                        orphans, self._orphans = self._orphans, []
                        self.journal.record(
                            now, jrn.CRASH_DETECTED,
                            replica=rid, n_orphans=len(orphans),
                        )
                        self._handle_orphans(orphans, now, dropped)
            self._deliver_recovery_events(now, dropped)
            if deadlines_active:
                # loud queued-deadline expiry: requests that can no longer
                # start service in time leave the queue at their deadline
                for rep in self.replicas:
                    if rep.queue:
                        self._expired.extend(rep.expire_queue(now))
            if adm is not None and adm.brownout is not None and (
                now >= adm.brownout.next_eval
            ):
                # lazy cadence: evaluated when the event loop is awake
                # anyway (never an event candidate, so an idle cluster
                # never spins on brownout ticks)
                est = self.router.min_estimated_delay()
                adm.brownout.evaluate(
                    now, est if est != float("inf") else adm.brownout.slo_ttft
                )
                adm.apply_stage()

            while i < len(specs) and specs[i].arrival_time <= now + _EPS:
                req = ClusterRequest(spec=specs[i])
                i += 1
                if adm is not None and adm.admit(req, now) is not None:
                    shed.append(req)  # refused at the front door
                    continue
                if self.router.dispatch(req, now) is None:
                    shed.append(req)  # pool down / queues full / delay bound
                    continue
                if adm is not None and adm.retry_budget is not None:
                    adm.retry_budget.note_admission(now)
            for rep in self.replicas:
                if rep.busy_until is not None and rep.busy_until <= now + _EPS:
                    done = rep.finish_step(now)
                    # realized interactive TTFTs feed the brownout
                    # controller's pressure signal
                    if adm is not None and adm.brownout is not None:
                        for r in done:
                            if (
                                r.priority == INTERACTIVE
                                and r.first_token_time is not None
                            ):
                                adm.brownout.observe_ttft(
                                    r.first_token_time - r.spec.arrival_time
                                )
                    # per-replica step-duration health signal (EMA + spike
                    # detection); sustained inflation -> DEGRADED ->
                    # deprioritized until the signal clears
                    rid = rep.replica_id
                    status = mon.observe(
                        f"replica-{rid}", rep.last_step_dur, t=now
                    )
                    if status == DEGRADED:
                        self.router.deprioritize(rid)
                    elif rid in self.router.deprioritized and rid not in self.router.excluded:
                        self.router.include(rid)
            t_arr = (
                specs[i].arrival_time if i < len(specs) else float("inf")
            )
            t_stop = t_arr
            if injector is not None and injector.next_time() is not None:
                # a step may not stretch past the next fault action: the
                # fault must be able to interrupt it (crash) or change the
                # duration of subsequent steps (degrade)
                t_stop = min(t_stop, injector.next_time())
            for t_d, _ in detections:
                t_stop = min(t_stop, t_d)
            # a migration delivery or backoff retry can hand a replica new
            # work mid-stretch, so step-jumping may not leap past them
            for t_m, _, _ in self._migrations:
                t_stop = min(t_stop, t_m)
            for t_r, _ in self._retries:
                t_stop = min(t_stop, t_r)
            for rep in self.replicas:
                if rep.busy_until is None and rep.has_work:
                    rep.start_step(now, t_stop)
                    steps += 1
            if steps > max_steps:
                raise RuntimeError(
                    f"cluster simulation exceeded {max_steps} engine steps"
                )

        completed = [r for rep in self.replicas for r in rep.completed]
        expired = self._expired
        n_accounted = len(completed) + len(dropped) + len(shed) + len(expired)
        assert n_accounted == len(specs), (
            f"request conservation violated: {len(specs)} submitted, "
            f"{len(completed)} completed + {len(shed)} shed + "
            f"{len(expired)} expired + {len(dropped)} dropped"
        )
        # exactly-once: no request may leave two outcome records — a
        # migrated/retried request must complete (or shed/expire/drop)
        # exactly once
        outcome_ids = [
            r.spec.req_id
            for lst in (completed, dropped, shed, expired)
            for r in lst
        ]
        assert len(outcome_ids) == len(set(outcome_ids)), (
            "duplicate request outcome detected"
        )
        if self.journal.replaying:
            self.journal.finish_replay()
        end_time = max((r.finish_time for r in completed), default=0.0)
        # shed reasons from the final outcomes (a retry refused once but
        # eventually completed is not a shed), admission-level refusals
        # included via the reason stamped at shed time
        shed_reasons: Dict[str, int] = {}
        for r in shed:
            key = r.shed_reason or "unknown"
            shed_reasons[key] = shed_reasons.get(key, 0) + 1
        return ClusterResult(
            completed=completed,
            horizon=horizon,
            end_time=end_time,
            replicas=self.replicas,
            n_submitted=len(specs),
            dropped=dropped,
            shed=shed,
            expired=expired,
            shed_reasons=shed_reasons,
            fault_log=injector.timeline_log() if injector is not None else [],
            transitions=list(mon.transitions),
            n_shed=len(shed),
            n_migrations=self.n_migrations,
            n_cold_redispatch=self.n_cold_redispatch,
            journal=self.journal,
            admission=adm.summary() if adm is not None else None,
        )
