"""Discrete-event cluster simulator: arrivals → router → replicas.

The event loop advances a global clock over two event kinds: request
arrivals (from the open-loop process) and replica step completions.  A
replica runs engine steps back-to-back while it has work; each step's
duration comes from the per-step cost model given the batch it actually
contains at step start — the standard trace-driven serving-simulator
structure (NeuPIMs lineage).

After the last arrival the cluster drains, so every submitted request
completes (request conservation is asserted and tested).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core.cost_model import SystemSpec
from repro.sim.engine import BatchState
from repro.sim.models import SimModelConfig
from .arrivals import ArrivalProcess, RequestSpec
from .metrics import SLO, summarize
from .replica import ClusterRequest, Replica, ReplicaConfig
from .router import Router

_EPS = 1e-12


@dataclass
class ClusterResult:
    completed: List[ClusterRequest]
    horizon: float
    end_time: float  # when the last request finished (drain included)
    replicas: List[Replica]
    n_submitted: int

    def report(self, slo: Optional[SLO] = None) -> Dict:
        return summarize(
            self.completed,
            self.horizon,
            slo=slo,
            replicas=self.replicas,
            end_time=self.end_time,
        )


class ClusterSimulator:
    """N identical replicas behind one router, fed by an arrival process."""

    def __init__(
        self,
        model: SimModelConfig,
        system: SystemSpec,
        policy: str = "sieve",
        n_replicas: int = 1,
        router_policy: str = "round_robin",
        replica_cfg: Optional[ReplicaConfig] = None,
        seed: int = 0,
        telemetry=None,
    ):
        # one Telemetry instance spans all replicas: each replica records
        # onto its own ``replica-{i}`` track in simulated time, so a run
        # exports as a single Perfetto timeline across the cluster
        self.replicas = [
            Replica(
                i, model, system, policy,
                cfg=replica_cfg, seed=seed, telemetry=telemetry,
            )
            for i in range(n_replicas)
        ]
        self.router = Router(router_policy, self.replicas)

    def set_router(self, router_policy: str) -> None:
        """Swap the routing policy while keeping the replicas (and their
        warmed cost tables + step-duration caches).  Sweeps over routers
        reuse one cluster instead of re-paying warmup per router."""
        self.router = Router(router_policy, self.replicas)

    def run(
        self, arrivals: ArrivalProcess, horizon: float, max_steps: int = 2_000_000
    ) -> ClusterResult:
        specs: List[RequestSpec] = arrivals.generate(horizon)
        return self.run_requests(specs, horizon, max_steps=max_steps)

    def run_requests(
        self, specs: List[RequestSpec], horizon: float, max_steps: int = 2_000_000
    ) -> ClusterResult:
        specs = sorted(specs, key=lambda s: s.arrival_time)
        for rep in self.replicas:  # allow back-to-back runs on one cluster
            rep.reset_requests()
        if specs:
            # Batched cost-table warmup on a representative batch state
            # (full decode slots at the trace's mean KV depth + one prefill
            # chunk wave).  One step_time_batch call per replica, before
            # the event loop — and warmup no longer depends on which
            # request happens to arrive first.
            mean_prompt = sum(s.prompt_len for s in specs) / len(specs)
            mean_out = sum(s.output_len for s in specs) / len(specs)
            for rep in self.replicas:
                cfg = rep.cfg
                rep.prewarm(
                    BatchState(
                        n_decode=cfg.n_slots,
                        seq=int(mean_prompt + mean_out / 2),
                        prefill_tokens=cfg.prefill_chunk
                        * cfg.max_prefills_per_step,
                    )
                )
        i = 0
        now = 0.0
        steps = 0
        while True:
            # next event: earliest of (next arrival, any step completion)
            t_next = specs[i].arrival_time if i < len(specs) else None
            for rep in self.replicas:
                if rep.busy_until is not None and (
                    t_next is None or rep.busy_until < t_next
                ):
                    t_next = rep.busy_until
            if t_next is None:
                break  # no arrivals left, nothing in flight -> drained
            now = t_next

            while i < len(specs) and specs[i].arrival_time <= now + _EPS:
                self.router.dispatch(ClusterRequest(spec=specs[i]), now)
                i += 1
            for rep in self.replicas:
                if rep.busy_until is not None and rep.busy_until <= now + _EPS:
                    rep.finish_step(now)
            t_arr = (
                specs[i].arrival_time if i < len(specs) else float("inf")
            )
            for rep in self.replicas:
                if rep.busy_until is None and rep.has_work:
                    rep.start_step(now, t_arr)
                    steps += 1
            if steps > max_steps:
                raise RuntimeError(
                    f"cluster simulation exceeded {max_steps} engine steps"
                )

        completed = [r for rep in self.replicas for r in rep.completed]
        assert len(completed) == len(specs), (
            f"request conservation violated: {len(specs)} submitted, "
            f"{len(completed)} completed"
        )
        end_time = max((r.finish_time for r in completed), default=0.0)
        return ClusterResult(
            completed=completed,
            horizon=horizon,
            end_time=end_time,
            replicas=self.replicas,
            n_submitted=len(specs),
        )
