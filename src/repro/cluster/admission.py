"""Overload-robust admission control for the cluster (and engine) layer.

A burst past the SLO knee must degrade into *explicit, prioritized*
refusals — not into unbounded queues that blow every SLO at once
(congestion collapse).  This module holds the four cooperating pieces the
simulator (and the live engine, via its brownout hook) compose:

* **priority classes + token buckets** — every request carries a
  ``priority`` (:data:`INTERACTIVE` / :data:`BATCH`) and an optional
  absolute ``deadline`` (latest acceptable *service start*).  Per-class
  :class:`TokenBucket` rate limits cap the admitted rate near measured
  capacity, so the replicas see at most what they can serve and the
  excess is shed at the front door with a computed ``retry_after``
  (backpressure to the arrival source) instead of rotting in a queue.
* **retry budget** (:class:`RetryBudget`) — a global rolling-window cap
  on crash re-dispatches (retries <= ``ratio`` x admissions per
  ``window``), layered on the jittered exponential backoff: a partial
  outage cannot amplify itself into a retry storm, because retries past
  the budget are *deferred* to the window's next free slot, never
  silently dropped.
* **circuit breaker** (:class:`CircuitBreaker`) — closed / open /
  half-open over the orphan re-dispatch path, driven by the
  :class:`~repro.faults.health.HealthMonitor` failure census: when the
  replica pool is gone, retries stop probing it entirely until a
  cooldown grants limited half-open probes.
* **staged brownout** (:class:`BrownoutController`) — an SLO-fed state
  machine ``healthy -> brownout-1 -> brownout-2 -> shed`` with
  hysteresis (``confirm`` consecutive breaches to escalate one stage,
  ``recover`` in-bound evaluations to de-escalate), reusing the PR-7
  :class:`~repro.faults.health.Transition` log so time-to-engage /
  time-to-clear fall out of the same machinery as fault detection.
  Stage 1 clamps the batch tier's ``max_new_tokens`` and cuts its bucket
  rate; stage 2 additionally cuts every class's admit rate (the live
  engine's analog is the GPU-only ``SieveState`` clamp on the
  no-recompile refresh path); stage 3 sheds the batch tier outright.

Everything is deterministic in simulated time — no wall clocks, no
unseeded randomness — so chaos/overload runs replay bit-identically.
"""

from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.faults.health import FAILED, HealthMonitor, Transition

# ---------------------------------------------------------------------------
# Priority classes / shed reasons / brownout stages
# ---------------------------------------------------------------------------

INTERACTIVE = "interactive"
BATCH = "batch"
PRIORITIES = (INTERACTIVE, BATCH)
_PRIORITY_RANK = {INTERACTIVE: 0, BATCH: 1}

# shed reasons (``ClusterRequest.shed_reason`` + per-reason counters)
SHED_RATE_LIMIT = "rate_limit"  # per-class token bucket empty
SHED_QUEUE_FULL = "queue_full"  # every candidate replica queue at max_queue
SHED_NO_REPLICA = "no_replica"  # every replica excluded (pool down)
SHED_DELAY_BOUND = "delay_bound"  # router's shed_delay estimate exceeded
SHED_BROWNOUT = "brownout"  # stage-3 brownout: batch tier refused

STAGE_HEALTHY = 0
STAGE_BROWNOUT1 = 1
STAGE_BROWNOUT2 = 2
STAGE_SHED = 3
STAGE_NAMES = ("healthy", "brownout1", "brownout2", "shed")


def priority_rank(priority: str) -> int:
    """Lower ranks admit first (unknown classes sort after batch)."""
    return _PRIORITY_RANK.get(priority, len(PRIORITIES))


def edf_key(req) -> Tuple[int, float, int]:
    """EDF queue ordering: priority class first, then earliest deadline,
    then submission order (so deadline-free traffic keeps exact FIFO
    semantics — the pre-admission behavior — as the tie-break)."""
    d = req.deadline
    return (
        priority_rank(req.priority),
        d if d is not None else float("inf"),
        req.queue_seq,
    )


# ---------------------------------------------------------------------------
# Token bucket
# ---------------------------------------------------------------------------


class TokenBucket:
    """Deterministic token bucket in simulated time.

    ``factor`` scales the refill rate without losing accumulated tokens —
    the brownout controller's admit-rate cut dials it down and back up.
    """

    def __init__(self, rate: float, burst: float):
        if rate <= 0 or burst < 1:
            raise ValueError(f"need rate > 0 and burst >= 1, got {rate}/{burst}")
        self.rate = float(rate)
        self.burst = float(burst)
        self.factor = 1.0
        self.tokens = float(burst)
        self._t = 0.0

    def reset(self) -> None:
        self.factor = 1.0
        self.tokens = self.burst
        self._t = 0.0

    def _refill(self, now: float) -> None:
        if now > self._t:
            self.tokens = min(
                self.burst, self.tokens + (now - self._t) * self.rate * self.factor
            )
            self._t = now

    def try_take(self, now: float) -> bool:
        self._refill(now)
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False

    def next_free(self, now: float) -> float:
        """Earliest time a token will be available (``now`` if one is)."""
        self._refill(now)
        if self.tokens >= 1.0:
            return now
        eff = self.rate * self.factor
        if eff <= 0.0:
            return float("inf")
        return now + (1.0 - self.tokens) / eff


# ---------------------------------------------------------------------------
# Retry budget
# ---------------------------------------------------------------------------


class RetryBudget:
    """Rolling-window global cap: retries <= max(min_retries, ratio x
    admissions in the trailing ``window`` seconds).

    :meth:`acquire_at` never refuses outright — a retry past the budget is
    *deferred* to the earliest time a slot frees (the oldest in-window
    retry ageing out), which is exactly the storm-damping semantics: the
    retry pressure is spread out, not amplified or lost.
    """

    def __init__(self, window: float = 0.5, ratio: float = 0.25, min_retries: int = 2):
        if window <= 0 or ratio < 0 or min_retries < 1:
            raise ValueError(
                f"need window > 0, ratio >= 0, min_retries >= 1; "
                f"got {window}/{ratio}/{min_retries}"
            )
        self.window = float(window)
        self.ratio = float(ratio)
        self.min_retries = int(min_retries)
        self.reset()

    def reset(self) -> None:
        self._admissions: List[float] = []
        self._retries: List[float] = []
        self.n_admissions = 0
        self.n_retries = 0
        self.n_deferred = 0
        # worst observed (retries in window) / allowance — the "stayed
        # under budget" gate is peak_utilization <= 1.0
        self.peak_utilization = 0.0

    def _prune(self, t: float) -> None:
        lo = t - self.window
        del self._admissions[: bisect.bisect_left(self._admissions, lo)]
        del self._retries[: bisect.bisect_left(self._retries, lo)]

    def note_admission(self, now: float) -> None:
        bisect.insort(self._admissions, now)
        self.n_admissions += 1

    def allowance(self, now: float) -> int:
        self._prune(now)
        return max(self.min_retries, int(self.ratio * len(self._admissions)))

    def acquire_at(self, now: float) -> float:
        """Register one retry; returns the earliest time it may fire
        (``now`` when in budget, else deferred to the window's next free
        slot).  The retry is booked at the returned time, so back-to-back
        acquisitions during a storm serialize onto the budget."""
        self._prune(now)
        t = now
        allowed = max(self.min_retries, int(self.ratio * len(self._admissions)))
        n_in = len([x for x in self._retries if x > t - self.window])
        if n_in >= allowed:
            # deferred: the slot frees when the oldest booked retry ages
            # out of the window (allowance growth from new admissions can
            # only make this earlier; we take the deterministic bound)
            idx = len(self._retries) - allowed
            t = self._retries[max(idx, 0)] + self.window
            self.n_deferred += 1
        bisect.insort(self._retries, t)
        self.n_retries += 1
        util = (n_in + 1) / max(allowed, 1)
        self.peak_utilization = max(self.peak_utilization, min(util, 1.0))
        return t

    def stats(self) -> Dict[str, float]:
        return {
            "n_admissions": self.n_admissions,
            "n_retries": self.n_retries,
            "n_deferred": self.n_deferred,
            "peak_utilization": self.peak_utilization,
            "window": self.window,
            "ratio": self.ratio,
        }


# ---------------------------------------------------------------------------
# Circuit breaker
# ---------------------------------------------------------------------------

BREAKER_CLOSED = "closed"
BREAKER_OPEN = "open"
BREAKER_HALF_OPEN = "half_open"
_BREAKER_CODE = {BREAKER_CLOSED: 0.0, BREAKER_HALF_OPEN: 1.0, BREAKER_OPEN: 2.0}


class CircuitBreaker:
    """Closed / open / half-open gate on the orphan re-dispatch path.

    Opens after ``fail_threshold`` consecutive dispatch failures, or
    immediately when the :class:`HealthMonitor` census reports the whole
    pool FAILED (:meth:`sync_health` — the "driven by HealthMonitor"
    path).  After ``cooldown`` it half-opens and grants
    ``half_open_probes`` probe dispatches; a success closes it, a failure
    re-opens.  A fresh probe allowance is granted every further cooldown
    while half-open, so the breaker can never wedge the retry path shut
    forever (liveness: every deferred retry eventually gets a probe).

    Transitions reuse :class:`repro.faults.health.Transition` (target
    ``"breaker"``), so chaos reports render breaker flips next to health
    flips with the same TTD machinery.
    """

    def __init__(
        self,
        fail_threshold: int = 3,
        cooldown: float = 0.25,
        half_open_probes: int = 1,
        telemetry=None,
    ):
        if fail_threshold < 1 or cooldown <= 0 or half_open_probes < 1:
            raise ValueError("bad breaker parameters")
        self.fail_threshold = int(fail_threshold)
        self.cooldown = float(cooldown)
        self.half_open_probes = int(half_open_probes)
        self.tel = telemetry
        self.reset()

    def reset(self) -> None:
        self.state = BREAKER_CLOSED
        self._fail_streak = 0
        self._opened_at = 0.0
        self._probe_grant_t = 0.0
        self._probes_left = 0
        self.n_opens = 0
        self.n_probes = 0
        self.transitions: List[Transition] = []

    def _set(self, new: str, t: float, reason: str) -> None:
        if new == self.state:
            return
        self.transitions.append(
            Transition(t=t, target="breaker", old=self.state, new=new, reason=reason)
        )
        self.state = new
        if new == BREAKER_OPEN:
            self.n_opens += 1
            self._opened_at = t
        if self.tel is not None and self.tel.enabled:
            self.tel.point(
                "breaker/state", _BREAKER_CODE[new], t_s=t, track="cluster"
            )

    def poll(self, now: float) -> str:
        """Advance time-driven transitions; returns the current state."""
        if self.state == BREAKER_OPEN and now >= self._opened_at + self.cooldown:
            self._set(BREAKER_HALF_OPEN, now, "cooldown elapsed")
            self._probes_left = self.half_open_probes
            self._probe_grant_t = now
        elif (
            self.state == BREAKER_HALF_OPEN
            and self._probes_left <= 0
            and now >= self._probe_grant_t + self.cooldown
        ):
            # probes were consumed without a verdict: grant another round
            self._probes_left = self.half_open_probes
            self._probe_grant_t = now
        return self.state

    def allow(self, now: float) -> bool:
        """May a (re-)dispatch proceed right now?  Half-open consumes one
        probe per grant."""
        st = self.poll(now)
        if st == BREAKER_CLOSED:
            return True
        if st == BREAKER_HALF_OPEN and self._probes_left > 0:
            self._probes_left -= 1
            self.n_probes += 1
            return True
        return False

    def retry_at(self, now: float) -> float:
        """When a refused dispatch should try again."""
        if self.state == BREAKER_OPEN:
            return max(self._opened_at + self.cooldown, now + 1e-3)
        return now + self.cooldown  # half-open, probes exhausted

    def on_success(self, now: float) -> None:
        self._fail_streak = 0
        if self.state != BREAKER_CLOSED:
            self._set(BREAKER_CLOSED, now, "probe succeeded")

    def on_failure(self, now: float) -> None:
        self._fail_streak += 1
        if self.state == BREAKER_HALF_OPEN:
            self._set(BREAKER_OPEN, now, "probe failed")
        elif (
            self.state == BREAKER_CLOSED
            and self._fail_streak >= self.fail_threshold
        ):
            self._set(BREAKER_OPEN, now, f"{self._fail_streak} consecutive failures")

    def sync_health(self, mon: HealthMonitor, now: float) -> None:
        """HealthMonitor drive: a fully-FAILED replica census trips the
        breaker without waiting for ``fail_threshold`` dispatch failures."""
        counts = mon.status_counts(prefix="replica-")
        n = sum(counts.values())
        if n > 0 and counts.get(FAILED, 0) >= n and self.state == BREAKER_CLOSED:
            self._set(BREAKER_OPEN, now, "health: all replicas failed")

    def stats(self) -> Dict[str, object]:
        return {
            "state": self.state,
            "n_opens": self.n_opens,
            "n_probes": self.n_probes,
            "transitions": [
                [tr.t, tr.old, tr.new, tr.reason] for tr in self.transitions
            ],
        }


# ---------------------------------------------------------------------------
# Staged brownout
# ---------------------------------------------------------------------------


class BrownoutController:
    """SLO-fed staged-degradation state machine with hysteresis.

    The pressure signal is ``max(interactive-TTFT EMA, estimated queue
    delay)`` in units of the TTFT SLO.  Escalation to stage ``k+1``
    requires ``confirm`` consecutive evaluations above ``enter[k]`` x SLO;
    de-escalation requires ``recover`` consecutive evaluations below
    ``exit_frac * enter[k-1]`` x SLO — one burst window never flips the
    stage, and the enter/exit gap prevents limit-cycling at a threshold.
    """

    def __init__(
        self,
        slo_ttft: float,
        enter: Tuple[float, float, float] = (0.5, 1.0, 2.0),
        exit_frac: float = 0.6,
        confirm: int = 2,
        recover: int = 3,
        eval_every: float = 0.05,
        alpha: float = 0.3,
        telemetry=None,
    ):
        if slo_ttft <= 0:
            raise ValueError("brownout needs a positive TTFT SLO")
        if not (len(enter) == 3 and all(a < b for a, b in zip(enter, enter[1:]))):
            raise ValueError(f"enter thresholds must be 3 increasing values: {enter}")
        if not (0 < exit_frac < 1):
            raise ValueError("exit_frac must be in (0, 1)")
        self.slo_ttft = float(slo_ttft)
        self.enter = tuple(float(x) * slo_ttft for x in enter)
        self.exit_frac = float(exit_frac)
        self.confirm = int(confirm)
        self.recover = int(recover)
        self.eval_every = float(eval_every)
        self.alpha = float(alpha)
        self.tel = telemetry
        self.reset()

    def reset(self) -> None:
        self.stage = STAGE_HEALTHY
        self.ema_ttft: Optional[float] = None
        self._hi_streak = 0
        self._lo_streak = 0
        self.next_eval = 0.0
        self.n_evals = 0
        self.transitions: List[Transition] = []

    def observe_ttft(self, ttft: float) -> None:
        """Feed one realized interactive TTFT (completion-time signal)."""
        if self.ema_ttft is None:
            self.ema_ttft = float(ttft)
        else:
            self.ema_ttft = (1 - self.alpha) * self.ema_ttft + self.alpha * float(ttft)

    def signal(self, est_delay: float) -> float:
        return max(self.ema_ttft or 0.0, est_delay)

    def _set_stage(self, new: int, t: float, reason: str) -> None:
        self.transitions.append(
            Transition(
                t=t,
                target="brownout",
                old=STAGE_NAMES[self.stage],
                new=STAGE_NAMES[new],
                reason=reason,
            )
        )
        self.stage = new
        if self.tel is not None and self.tel.enabled:
            self.tel.point("brownout/stage", float(new), t_s=t, track="cluster")

    def evaluate(self, now: float, est_delay: float) -> int:
        """One cadence tick; returns the (possibly changed) stage."""
        self.next_eval = now + self.eval_every
        self.n_evals += 1
        sig = self.signal(est_delay)
        if self.stage < STAGE_SHED and sig > self.enter[self.stage]:
            self._hi_streak += 1
            self._lo_streak = 0
            if self._hi_streak >= self.confirm:
                self._set_stage(
                    self.stage + 1, now,
                    f"pressure {sig:.3f}s > {self.enter[self.stage]:.3f}s",
                )
                self._hi_streak = 0
        elif (
            self.stage > STAGE_HEALTHY
            and sig < self.exit_frac * self.enter[self.stage - 1]
        ):
            self._lo_streak += 1
            self._hi_streak = 0
            if self._lo_streak >= self.recover:
                self._set_stage(
                    self.stage - 1, now,
                    f"pressure {sig:.3f}s < "
                    f"{self.exit_frac * self.enter[self.stage - 1]:.3f}s",
                )
                self._lo_streak = 0
        else:
            self._hi_streak = 0
            self._lo_streak = 0
        return self.stage

    def time_to_engage(self, t0: float) -> Optional[float]:
        """Delay from ``t0`` to the first escalation at/after it (the TTD
        analog for overload instead of faults)."""
        for tr in self.transitions:
            if tr.t >= t0 and STAGE_NAMES.index(tr.new) > STAGE_NAMES.index(tr.old):
                return tr.t - t0
        return None

    def max_stage(self) -> int:
        worst = self.stage
        for tr in self.transitions:
            worst = max(worst, STAGE_NAMES.index(tr.new))
        return worst


# ---------------------------------------------------------------------------
# Admission controller (front door)
# ---------------------------------------------------------------------------


@dataclass
class AdmissionConfig:
    """Knobs for the whole overload-robustness layer.  ``None`` rates
    disable that class's bucket; ``brownout_ttft=None`` disables the
    brownout controller; ``retry_ratio=None`` disables the retry budget;
    ``breaker=False`` disables the circuit breaker."""

    # per-class token buckets (requests/second, burst in requests)
    interactive_rate: Optional[float] = None
    interactive_burst: float = 16.0
    batch_rate: Optional[float] = None
    batch_burst: float = 16.0
    # retry budget (global, rolling window)
    retry_ratio: Optional[float] = 0.25
    retry_window: float = 0.5
    retry_min: int = 2
    # circuit breaker on the re-dispatch path
    breaker: bool = True
    breaker_fail_threshold: int = 3
    breaker_cooldown: float = 0.25
    breaker_probes: int = 1
    # staged brownout (enabled when an SLO target is given)
    brownout_ttft: Optional[float] = None
    brownout_enter: Tuple[float, float, float] = (0.5, 1.0, 2.0)
    brownout_exit_frac: float = 0.6
    brownout_confirm: int = 2
    brownout_recover: int = 3
    brownout_eval_every: float = 0.05
    brownout_alpha: float = 0.3
    # stage actions: batch max_new_tokens clamp (stage >= 1), batch
    # bucket-rate cut (stage >= 1), global admit-rate cut (stage >= 2)
    brownout_batch_max_new: int = 8
    brownout_batch_rate_factor: float = 0.5
    brownout_admit_factor: float = 0.5


class AdmissionController:
    """The cluster's front door: per-class token buckets + the brownout
    stage's admit policy, with the retry budget and circuit breaker
    attached for the simulator's re-dispatch path."""

    def __init__(self, cfg: AdmissionConfig, telemetry=None):
        self.cfg = cfg
        self.tel = telemetry
        self._bucket_specs = {
            INTERACTIVE: (cfg.interactive_rate, cfg.interactive_burst),
            BATCH: (cfg.batch_rate, cfg.batch_burst),
        }
        self.buckets: Dict[str, TokenBucket] = {
            cls: TokenBucket(rate, burst)
            for cls, (rate, burst) in self._bucket_specs.items()
            if rate is not None
        }
        self.retry_budget = (
            RetryBudget(cfg.retry_window, cfg.retry_ratio, cfg.retry_min)
            if cfg.retry_ratio is not None
            else None
        )
        self.breaker = (
            CircuitBreaker(
                cfg.breaker_fail_threshold,
                cfg.breaker_cooldown,
                cfg.breaker_probes,
                telemetry=telemetry,
            )
            if cfg.breaker
            else None
        )
        self.brownout = (
            BrownoutController(
                cfg.brownout_ttft,
                enter=cfg.brownout_enter,
                exit_frac=cfg.brownout_exit_frac,
                confirm=cfg.brownout_confirm,
                recover=cfg.brownout_recover,
                eval_every=cfg.brownout_eval_every,
                alpha=cfg.brownout_alpha,
                telemetry=telemetry,
            )
            if cfg.brownout_ttft is not None
            else None
        )
        self.reset()

    def reset(self) -> None:
        for b in self.buckets.values():
            b.reset()
        if self.retry_budget is not None:
            self.retry_budget.reset()
        if self.breaker is not None:
            self.breaker.reset()
        if self.brownout is not None:
            self.brownout.reset()
        self.n_admitted: Dict[str, int] = {cls: 0 for cls in PRIORITIES}
        self.n_clamped = 0
        self.shed_reasons: Dict[str, int] = {}

    # ---- brownout stage actions -----------------------------------------
    @property
    def stage(self) -> int:
        return self.brownout.stage if self.brownout is not None else STAGE_HEALTHY

    def apply_stage(self) -> None:
        """Re-derive bucket rate factors from the current stage."""
        stage = self.stage
        cut = self.cfg.brownout_admit_factor if stage >= STAGE_BROWNOUT2 else 1.0
        for cls, b in self.buckets.items():
            f = cut
            if cls == BATCH and stage >= STAGE_BROWNOUT1:
                f *= self.cfg.brownout_batch_rate_factor
            b.factor = f

    # ---- the front door --------------------------------------------------
    def count_shed(self, reason: str) -> None:
        self.shed_reasons[reason] = self.shed_reasons.get(reason, 0) + 1

    def admit(self, req, now: float) -> Optional[str]:
        """``None`` when admitted (stage-1 batch clamp applied in place),
        else the shed reason.  Shed requests get ``retry_after`` stamped
        so the arrival source sees backpressure, not a silent refusal."""
        cls = req.priority
        if cls == BATCH and self.stage >= STAGE_SHED:
            self.count_shed(SHED_BROWNOUT)
            req.shed_reason = SHED_BROWNOUT
            req.retry_after = self.retry_after(req, now)
            return SHED_BROWNOUT
        bucket = self.buckets.get(cls)
        if bucket is not None and not bucket.try_take(now):
            self.count_shed(SHED_RATE_LIMIT)
            req.shed_reason = SHED_RATE_LIMIT
            req.retry_after = self.retry_after(req, now)
            return SHED_RATE_LIMIT
        if cls == BATCH and self.stage >= STAGE_BROWNOUT1:
            cap = self.cfg.brownout_batch_max_new
            if req.max_output is None or req.max_output > cap:
                req.max_output = cap
                self.n_clamped += 1
        self.n_admitted[cls] = self.n_admitted.get(cls, 0) + 1
        return None

    def retry_after(self, req, now: float) -> float:
        """Backpressure hint: how long the source should wait before
        re-offering a shed request."""
        bucket = self.buckets.get(req.priority)
        if bucket is not None:
            t = bucket.next_free(now)
            if t != float("inf"):
                return max(t - now, 1e-3)
        if self.brownout is not None and self.stage >= STAGE_BROWNOUT1:
            return self.brownout.eval_every * self.brownout.recover
        return 0.05

    # ---- reporting -------------------------------------------------------
    def summary(self) -> Dict[str, object]:
        out: Dict[str, object] = {
            "n_admitted": dict(self.n_admitted),
            "n_clamped": self.n_clamped,
            "shed_reasons": dict(self.shed_reasons),
            "stage": STAGE_NAMES[self.stage],
        }
        if self.brownout is not None:
            out["brownout"] = {
                "stage": STAGE_NAMES[self.brownout.stage],
                "max_stage": STAGE_NAMES[self.brownout.max_stage()],
                "n_evals": self.brownout.n_evals,
                "transitions": [
                    [tr.t, tr.old, tr.new, tr.reason]
                    for tr in self.brownout.transitions
                ],
            }
        if self.retry_budget is not None:
            out["retry_budget"] = self.retry_budget.stats()
        if self.breaker is not None:
            out["breaker"] = self.breaker.stats()
        return out
