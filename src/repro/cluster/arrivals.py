"""Open-loop request arrival processes for the cluster simulator.

The paper evaluates one steady-state decode step; a production cluster is
decided by tail latency under *open-loop* traffic — requests arrive on
their own clock whether or not the system keeps up.  This module provides
the seeded arrival generators the NeuPIMs-lineage simulators drive their
evaluations with:

* :class:`PoissonProcess` — memoryless baseline traffic at a fixed rate;
* :class:`MMPPProcess` — 2-state Markov-modulated Poisson (bursty traffic:
  a calm state and a burst state with exponentially distributed dwell
  times), the standard model for the diurnal/bursty request dynamics that
  "Patterns behind Chaos" reports for production MoE serving;
* :class:`TraceReplay` — replay of a recorded ``(time, prompt_len,
  output_len)`` request trace (JSON or in-memory), for trace-driven
  evaluation.

Prompt/output lengths come from a :class:`LengthModel` (lognormal by
default — request lengths are heavy-tailed in production traces — or
fixed for controlled experiments).  Everything is deterministic given the
seed.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, replace
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class RequestSpec:
    """One request of the offered load (immutable workload description).

    ``priority`` is the request's service class (``"interactive"`` or
    ``"batch"``); ``deadline`` is the absolute latest acceptable *service
    start* (first-token) time, or None for no deadline.  Both default to
    the pre-admission behavior (interactive, no deadline).
    """

    req_id: int
    arrival_time: float  # seconds since trace start
    prompt_len: int
    output_len: int
    priority: str = "interactive"
    deadline: Optional[float] = None


@dataclass(frozen=True)
class ClassMix:
    """Priority/deadline assignment for generated arrivals.

    A fraction ``p_interactive`` of requests (Bernoulli per request, from
    the process's own seeded RNG) are interactive with
    ``deadline = arrival + interactive_slack`` (None slack → no
    deadline); the rest are batch with ``batch_slack`` likewise.
    """

    p_interactive: float = 1.0
    interactive_slack: Optional[float] = None
    batch_slack: Optional[float] = None

    def assign(
        self, specs: List["RequestSpec"], rng: np.random.Generator
    ) -> List["RequestSpec"]:
        if not specs:
            return specs
        draws = rng.random(len(specs))
        out = []
        for spec, u in zip(specs, draws):
            interactive = bool(u < self.p_interactive)
            slack = self.interactive_slack if interactive else self.batch_slack
            out.append(
                replace(
                    spec,
                    priority="interactive" if interactive else "batch",
                    deadline=None if slack is None else spec.arrival_time + slack,
                )
            )
        return out


@dataclass(frozen=True)
class LengthModel:
    """Sampler for (prompt_len, output_len) pairs.

    ``kind="lognormal"``: lengths ~ LogNormal with the given means (the
    sigma parameters are the log-space spreads), clipped to [1, max].
    ``kind="fixed"``: every request gets exactly the mean lengths.
    """

    kind: str = "lognormal"
    prompt_mean: float = 512.0
    prompt_sigma: float = 0.6
    prompt_max: int = 8192
    output_mean: float = 128.0
    output_sigma: float = 0.6
    output_max: int = 2048

    def sample(self, rng: np.random.Generator, n: int) -> Tuple[np.ndarray, np.ndarray]:
        if self.kind == "fixed":
            p = np.full(n, int(self.prompt_mean), np.int64)
            o = np.full(n, int(self.output_mean), np.int64)
            return p, o
        if self.kind != "lognormal":
            raise ValueError(f"unknown length model kind: {self.kind}")

        def _draw(mean: float, sigma: float, cap: int) -> np.ndarray:
            # parameterize so the *linear-space* mean equals ``mean``
            mu = np.log(mean) - 0.5 * sigma**2
            x = rng.lognormal(mu, sigma, size=n)
            return np.clip(np.round(x), 1, cap).astype(np.int64)

        return (
            _draw(self.prompt_mean, self.prompt_sigma, self.prompt_max),
            _draw(self.output_mean, self.output_sigma, self.output_max),
        )


class ArrivalProcess:
    """Base: ``generate(horizon)`` returns arrivals in [0, horizon), sorted."""

    def generate(self, horizon: float) -> List[RequestSpec]:
        raise NotImplementedError


def _make_specs(
    times: np.ndarray, lengths: LengthModel, rng: np.random.Generator
) -> List[RequestSpec]:
    plens, olens = lengths.sample(rng, len(times))
    return [
        RequestSpec(
            req_id=i,
            arrival_time=float(t),
            prompt_len=int(p),
            output_len=int(o),
        )
        for i, (t, p, o) in enumerate(zip(times, plens, olens))
    ]


class PoissonProcess(ArrivalProcess):
    """Homogeneous Poisson arrivals at ``rate`` requests/second."""

    def __init__(
        self,
        rate: float,
        lengths: Optional[LengthModel] = None,
        seed: int = 0,
        mix: Optional[ClassMix] = None,
    ):
        assert rate > 0
        self.rate = rate
        self.lengths = lengths or LengthModel()
        self.seed = seed
        self.mix = mix

    def generate(self, horizon: float) -> List[RequestSpec]:
        rng = np.random.default_rng(self.seed)
        # draw enough exponential gaps to cover the horizon, then trim
        n_guess = max(int(self.rate * horizon * 1.5) + 16, 16)
        times: List[float] = []
        t = 0.0
        while True:
            gaps = rng.exponential(1.0 / self.rate, size=n_guess)
            for g in gaps:
                t += g
                if t >= horizon:
                    specs = _make_specs(np.array(times), self.lengths, rng)
                    return self.mix.assign(specs, rng) if self.mix else specs
                times.append(t)


class MMPPProcess(ArrivalProcess):
    """2-state Markov-modulated Poisson process (calm / burst).

    The process dwells in each state for an exponential time
    (``mean_dwell``) and emits Poisson arrivals at that state's rate.
    ``rate_burst >> rate_calm`` produces the correlated bursts that expose
    queueing behavior a plain Poisson process at the same mean rate hides.
    """

    def __init__(
        self,
        rate_calm: float,
        rate_burst: float,
        mean_dwell_calm: float = 2.0,
        mean_dwell_burst: float = 0.5,
        lengths: Optional[LengthModel] = None,
        seed: int = 0,
        mix: Optional[ClassMix] = None,
    ):
        assert rate_calm > 0 and rate_burst > 0
        self.rates = (rate_calm, rate_burst)
        self.dwells = (mean_dwell_calm, mean_dwell_burst)
        self.lengths = lengths or LengthModel()
        self.seed = seed
        self.mix = mix

    @property
    def mean_rate(self) -> float:
        """Long-run average arrival rate (dwell-time weighted)."""
        (rc, rb), (dc, db) = self.rates, self.dwells
        return (rc * dc + rb * db) / (dc + db)

    def generate(self, horizon: float) -> List[RequestSpec]:
        rng = np.random.default_rng(self.seed)
        times: List[float] = []
        t, state = 0.0, 0
        while t < horizon:
            dwell = rng.exponential(self.dwells[state])
            t_end = min(t + dwell, horizon)
            rate = self.rates[state]
            tt = t
            while True:
                tt += rng.exponential(1.0 / rate)
                if tt >= t_end:
                    break
                times.append(tt)
            t, state = t_end, 1 - state
        specs = _make_specs(np.array(times), self.lengths, rng)
        return self.mix.assign(specs, rng) if self.mix else specs


class TraceReplay(ArrivalProcess):
    """Replay a recorded request trace.

    ``records`` is a sequence of ``(arrival_time, prompt_len, output_len)``
    tuples (or dicts with those keys).  ``from_json`` loads the same
    structure from a file, so recorded production traces can be replayed
    against any cluster configuration.  ``time_scale`` compresses or
    stretches the trace clock (e.g. 0.5 doubles the offered rate).
    """

    def __init__(
        self,
        records: Sequence,
        time_scale: float = 1.0,
        mix: Optional[ClassMix] = None,
        seed: int = 0,
    ):
        rows = []
        for r in records:
            if isinstance(r, dict):
                rows.append(
                    (float(r["arrival_time"]), int(r["prompt_len"]), int(r["output_len"]))
                )
            else:
                t, p, o = r
                rows.append((float(t), int(p), int(o)))
        rows.sort(key=lambda x: x[0])
        self.records = rows
        self.time_scale = time_scale
        self.mix = mix
        self.seed = seed

    @classmethod
    def from_json(cls, path: str, time_scale: float = 1.0) -> "TraceReplay":
        with open(path) as f:
            return cls(json.load(f), time_scale=time_scale)

    def generate(self, horizon: float) -> List[RequestSpec]:
        out = []
        for i, (t, p, o) in enumerate(self.records):
            ts = t * self.time_scale
            if ts >= horizon:
                break
            out.append(
                RequestSpec(req_id=i, arrival_time=ts, prompt_len=p, output_len=o)
            )
        if self.mix:
            out = self.mix.assign(out, np.random.default_rng(self.seed))
        return out
