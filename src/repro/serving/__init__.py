"""Sieve serving runtime: continuous batching + scheduler-in-the-loop."""

from .batching import BatchingConfig, PagedKVCache, SlotScheduler  # noqa: F401
from .engine import EngineStats, ServingEngine  # noqa: F401
from .request import Request  # noqa: F401
