"""Sieve serving runtime: continuous batching + scheduler-in-the-loop."""

from .batching import BatchingConfig, SlotScheduler  # noqa: F401
from .engine import EngineStats, ServingEngine  # noqa: F401
from .request import Request  # noqa: F401
