"""Request / SLO structures for the serving engine."""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import List, Optional

_ids = itertools.count()


@dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    arrival_time: float = 0.0
    req_id: int = field(default_factory=lambda: next(_ids))

    # runtime state
    generated: List[int] = field(default_factory=list)
    prefill_done: int = 0  # tokens of the prompt already prefilled
    slot: Optional[int] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.max_new_tokens:
            return True
        return bool(
            self.eos_id is not None
            and self.generated
            and self.generated[-1] == self.eos_id
        )

    @property
    def position(self) -> int:
        """Next position to write in the KV timeline."""
        return self.prefill_done + len(self.generated)
