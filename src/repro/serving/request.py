"""Request / SLO structures for the serving engine."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

# Monotone process-wide id allocator.  A plain counter (not
# itertools.count) so snapshot restore can advance it past every restored
# request's id — a fresh process replaying a snapshot must never hand a
# new request an id that is already in flight.
_next_id = 0


def _alloc_id() -> int:
    global _next_id
    i = _next_id
    _next_id += 1
    return i


def advance_request_ids(min_next: int) -> None:
    """Ensure future ids start at >= ``min_next`` (snapshot restore)."""
    global _next_id
    _next_id = max(_next_id, int(min_next))


@dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    arrival_time: float = 0.0
    req_id: int = field(default_factory=_alloc_id)
    # admission-control metadata: service class ("interactive"/"batch")
    # and the latest acceptable service-start time (engine clock, same
    # base as arrival_time); None = no deadline
    priority: str = "interactive"
    deadline: Optional[float] = None

    # runtime state
    generated: List[int] = field(default_factory=list)
    prefill_done: int = 0  # tokens of the prompt already prefilled
    slot: Optional[int] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    truncated: bool = False  # hit the KV capacity (max_seq) before eos
    expired: bool = False  # deadline passed while still queued

    @property
    def done(self) -> bool:
        if self.truncated or self.expired:
            return True
        if len(self.generated) >= self.max_new_tokens:
            return True
        return bool(
            self.eos_id is not None
            and self.generated
            and self.generated[-1] == self.eos_id
        )

    @property
    def position(self) -> int:
        """Next position to write in the KV timeline."""
        return self.prefill_done + len(self.generated)

    # ---- snapshot (de)serialization ----------------------------------
    def to_state(self) -> Dict[str, Any]:
        """Plain-data form for engine snapshots.  The wall-clock fields
        (``first_token_time``/``finish_time``) are ``perf_counter``
        readings, process-relative — they round-trip for completeness but
        only latency *within* one process is meaningful."""
        return {
            "prompt": [int(t) for t in self.prompt],
            "max_new_tokens": self.max_new_tokens,
            "eos_id": self.eos_id,
            "arrival_time": self.arrival_time,
            "req_id": self.req_id,
            "generated": [int(t) for t in self.generated],
            "prefill_done": self.prefill_done,
            "slot": self.slot,
            "first_token_time": self.first_token_time,
            "finish_time": self.finish_time,
            "truncated": self.truncated,
            "priority": self.priority,
            "deadline": self.deadline,
            "expired": self.expired,
        }

    @classmethod
    def from_state(cls, d: Dict[str, Any]) -> "Request":
        req = cls(
            prompt=[int(t) for t in d["prompt"]],
            max_new_tokens=int(d["max_new_tokens"]),
            eos_id=None if d["eos_id"] is None else int(d["eos_id"]),
            arrival_time=float(d["arrival_time"]),
            req_id=int(d["req_id"]),
        )
        req.generated = [int(t) for t in d["generated"]]
        req.prefill_done = int(d["prefill_done"])
        req.slot = None if d["slot"] is None else int(d["slot"])
        req.first_token_time = d["first_token_time"]
        req.finish_time = d["finish_time"]
        req.truncated = bool(d.get("truncated", False))  # pre-paged snapshots
        # pre-admission snapshots carry no class/deadline fields
        req.priority = str(d.get("priority", "interactive"))
        req.deadline = d.get("deadline", None)
        req.expired = bool(d.get("expired", False))
        advance_request_ids(req.req_id + 1)
        return req
