"""Sieve serving engine: continuous batching + runtime scheduler loop.

This is the runtime-framework half of the paper (§6) in executable form:
per engine step it

  1. admits requests into KV slots and runs (chunked) prefill;
  2. runs one batched decode step — the compiled step returns per-layer
     expert token counts (the routing map ③ of Fig 8);
  3. feeds observed counts into the EMA cost table and runs the Sieve
     scheduler per MoE layer, recording the GPU/PIM partitions and their
     estimated times (on TPU these partitions select grouped-GEMM vs
     streaming-GEMV kernels; the decision trail is exported for analysis);
  4. under ``MoEConfig.expert_exec="dual_path_cost"``, exports the cost
     table + cost model into a device-resident ``SieveState`` on the EMA
     refresh cadence (``sieve_refresh_every`` steps, skipped when the
     table version is unchanged) — the compiled prefill/decode steps read
     it as a fixed-shape array input, so the in-graph split follows the
     learned costs without ever recompiling.

The engine is hardware-agnostic: on this CPU container it serves reduced
models end-to-end (examples/serve_moe.py); on a TPU pod the same engine
drives the jit'd steps built by launch/serve.py.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.cost_model import CostModel, MoELayerSpec, SystemSpec, b200_pim_system
from repro.core.cost_table import CostTable
from repro.core.scheduler import schedule
from repro.core.scheduler_jax import SieveState, make_sieve_state
from repro.faults.health import HealthMonitor
from repro.models.model import LM
from repro.sim.dram import PimGemvModel
from repro.telemetry import StageProbes, Telemetry, TimingFeed
from repro.telemetry import default as default_telemetry
from .batching import BatchingConfig, PagedKVCache, SlotScheduler
from .request import Request

# cost-table feeding modes: "model" synthesizes PIM observations from the
# DRAM-timing proxy (PimGemvModel); "measured" drives the table from
# span-measured tail-stage probe durations (TimingFeed) on the refresh
# cadence — no DRAM-proxy lookups anywhere on the refresh path.
COST_SOURCES = ("model", "measured")

# cap on stage probes per refresh boundary (distinct tail counts measured);
# keeps the off-critical-path probe cost bounded per cadence
_MAX_TAIL_PROBES = 8

# fixed sentinel tail cell probed at every refresh boundary: its measured
# time vs the roofline model proxy is the PIM-health drift signal (a
# stationary ratio — the EMA baseline absorbs the hardware/model scale),
# and it keeps the feed's progress heartbeat alive on idle boundaries
_SENTINEL_TAIL = 1
_SENTINEL_PROBES = 3  # repeats per boundary; the mean damps OS jitter

# "PIM time" exported while the stack is flagged unhealthy: huge but
# finite float32 seconds, so the in-graph argmin picks the minimal
# feasible tail (GPU-only split) without any shape or dtype change — the
# compiled decode step never retraces on a health transition
_PIM_BLOCKED_TIME = 1e9


@dataclass
class EngineStats:
    steps: int = 0
    decode_tokens: int = 0
    prefill_tokens: int = 0
    wall_time: float = 0.0
    # capacity/dual-path overflow drops measured in-graph
    # (MoEOut.n_dropped summed over layers), next to the routed totals so
    # drop *rate* can sit beside TTFT/TPOT in reports
    dropped_tokens: int = 0
    routed_tokens: int = 0
    # requests force-finished at the KV capacity (max_seq) — the loud
    # alternative to the old silent clamp-and-overwrite of the last entry
    truncated_requests: int = 0
    # admission-control terminal outcomes: deadline passed while queued /
    # batch request refused at submit under brownout stage 3
    expired_requests: int = 0
    shed_requests: int = 0
    partitions: List[Dict] = field(default_factory=list)

    @property
    def throughput(self) -> float:
        return self.decode_tokens / self.wall_time if self.wall_time else 0.0

    @property
    def drop_rate(self) -> float:
        # defined as 0.0 before any token has been routed — an engine that
        # never generated a token must not divide by zero
        if self.routed_tokens <= 0:
            return 0.0
        return self.dropped_tokens / self.routed_tokens


class ServingEngine:
    def __init__(
        self,
        lm: LM,
        params: Any,
        batching: BatchingConfig,
        policy: str = "sieve",
        system: Optional[SystemSpec] = None,
        greedy: bool = True,
        seed: int = 0,
        sieve_refresh_every: int = 16,
        telemetry: Optional[Telemetry] = None,
        cost_source: str = "model",
        health: Optional[HealthMonitor] = None,
        brownout_batch_max_new: int = 8,
    ):
        if cost_source not in COST_SOURCES:
            raise ValueError(
                f"cost_source must be one of {COST_SOURCES}, got {cost_source!r}"
            )
        self.lm = lm
        self.params = params
        self.cfg = batching
        self.policy = policy
        self.sched = SlotScheduler(batching)
        self.greedy = greedy
        self.rng = np.random.default_rng(seed)
        self.stats = EngineStats()
        self.cost_source = cost_source
        # telemetry: explicit instance wins; otherwise the process default
        # (enabled iff REPRO_TELEMETRY is set — a shared no-op otherwise)
        self.tel = telemetry if telemetry is not None else default_telemetry()

        # paged KV: slots index a shared device block pool through a
        # host-side block table (allocated on admit/decode, freed on
        # retire); dense mode keeps the per-slot (max_seq, ...) buffers
        self.paged: Optional[PagedKVCache] = None
        if batching.paged:
            self.paged = PagedKVCache(batching)
            self.cache = lm.init_paged_cache(self.paged.n_pool, self.paged.page)
        else:
            self.cache = lm.init_cache(batching.n_slots, batching.max_seq)
        # The KV cache is donated on both compiled steps (argnum 2): the
        # engine rebinds ``self.cache`` to the returned cache every call,
        # so the stale buffers would otherwise survive as full-cache
        # copies — at decode that is a whole-cache memcpy per step.  With
        # donation XLA aliases cache-in to cache-out and the update is
        # in-place (pinned by tests/test_serving.py::TestBufferDonation).
        self._decode = jax.jit(lm.decode_step, donate_argnums=(2,))
        self._prefill_chunk = jax.jit(
            self._prefill_chunk_impl, static_argnums=(3,), donate_argnums=(2,)
        )

        # ---- Sieve runtime state (MoE archs only) ----
        arch = lm.arch
        self.is_moe = arch.moe is not None
        # cost-driven in-graph split: the compiled step consumes a
        # device-resident SieveState refreshed on the EMA update cadence
        self.uses_cost_split = (
            self.is_moe and arch.moe.expert_exec == "dual_path_cost"
        )
        self.sieve_refresh_every = max(int(sieve_refresh_every), 1)
        self.sieve_refreshes: List[int] = []  # step indices of re-exports
        self._sieve_state: Optional[SieveState] = None
        self._sieve_version = -1
        self._sieve_gpu_only = False
        # PIM health gate: flipped by _update_pim_health at refresh
        # boundaries; while False the sieve export clamps to GPU-only and
        # the measured feed is quarantined (model-proxy fallback)
        self.pim_healthy = True
        self.health = health
        # cluster-driven brownout stage (0 = healthy .. 3 = shed): stage 1+
        # clamps batch-tier max_new_tokens at submit, stage 2+ forces the
        # GPU-only sieve export, stage 3 refuses new batch requests
        self.brownout_stage = 0
        self.brownout_batch_max_new = max(int(brownout_batch_max_new), 1)
        if cost_source == "measured" and not self.is_moe:
            raise ValueError(
                "cost_source='measured' feeds the MoE cost table; "
                f"arch {arch.name!r} has no MoE layers"
            )
        # measured cost loop (built in the MoE branch below)
        self._probes: Optional[StageProbes] = None
        self._timing_feed: Optional[TimingFeed] = None
        self._pending_tail_counts: set = set()
        self._last_head_counts: List[int] = []
        self._last_decode_batch = 0
        self._last_kv_depth = 1
        self._jit_cache_seen = 0  # jit entries already counted as misses
        # per-layer metric names, built once (f-strings per step add up on
        # a ~5ms decode step)
        self._layer_metric_names: List[tuple] = []
        if self.is_moe:
            self.system = system or b200_pim_system()
            self.layer_spec = MoELayerSpec(
                d_model=arch.d_model,
                d_ff=arch.moe.d_expert,
                n_experts=arch.moe.n_experts,
                top_k=arch.moe.top_k,
                n_shared=arch.moe.n_shared,
            )
            self.cost_model = CostModel(system=self.system, layer=self.layer_spec)
            self._pim = (
                PimGemvModel(self.system.pim) if self.system.pim is not None else None
            )
            fallback = (
                self.cost_model.t_pim_gemv_roofline
                if self._pim is None
                else None
            )
            self.cost_table = CostTable(
                fallback=fallback or self.cost_model.t_pim_gemv_roofline
            )
            if cost_source == "measured":
                # the span buffer is the measurement record: if the caller
                # left telemetry disabled, the measured loop still needs a
                # live instance of its own (private — nothing else reads it)
                if not self.tel.enabled:
                    self.tel = Telemetry(enabled=True)
                attn = arch.attn
                attn_dims = (
                    (attn.n_heads, attn.n_kv_heads, attn.d_head)
                    if attn.kind == "gqa"
                    else None
                )
                self._probes = StageProbes(
                    arch.d_model,
                    arch.moe.d_expert,
                    self.tel,
                    attn_dims=attn_dims,
                    seed=seed,
                )
                self._timing_feed = TimingFeed(self.cost_table, self.tel)
                # health detection on the measured loop (the only cost
                # source that can silently break): sentinel drift vs the
                # roofline proxy + a feed-progress staleness watchdog.
                # PimGemvModel is never consulted — the measured path
                # stays DRAM-proxy-free even for its health reference.
                if self.health is None:
                    self.health = HealthMonitor(
                        threshold=4.0,
                        alpha=0.2,
                        warmup=1,
                        confirm=1,
                        recover=2,
                        stale_after=2,
                        telemetry=self.tel,
                    )
                self._roofline_t1 = self.cost_model.t_pim_gemv_roofline(
                    _SENTINEL_TAIL
                )
            if self.uses_cost_split:
                # per-expert counts are bounded by the step's token count
                # (n_slots decode tokens / max_seq prefill tokens); the jit
                # split clamps larger indices to the last table entry
                self._sieve_max_count = min(
                    4096, max(batching.n_slots, batching.max_seq, 64)
                )
                self._refresh_sieve_state(step=0)

    # ------------------------------------------------------------------
    def _refresh_sieve_state(self, step: int, gpu_only: bool = False) -> None:
        """Re-export (CostTable, CostModel) into the device-resident state.

        Fixed shapes (table depth and packed-params length never change),
        so the compiled prefill/decode steps see the same signature and a
        refresh can never trigger a retrace — the split simply reads new
        numbers.  Skipped when the table has not changed since the last
        export.

        The packed ``t_comm`` is evaluated at the decode-step nominal
        (``n_slots * top_k`` routed tokens); on this single-device engine
        (``ep_degree=1``) it is exactly 0 either way.  A multi-device
        engine feeding long prefills should export a per-phase state
        (ROADMAP open item) so the prefill split's comm floor is not
        understated.

        ``gpu_only=True`` (PIM flagged unhealthy) exports huge-but-finite
        PIM times instead of the table, so the in-graph argmin clamps to
        the minimal feasible tail — same shapes, same compiled step, zero
        jit-cache misses on a health transition.
        """
        if (
            self._sieve_state is not None
            and self.cost_table.version == self._sieve_version
            and gpu_only == self._sieve_gpu_only
        ):
            return
        stale = self._sieve_state
        state = make_sieve_state(
            self.cost_table,
            self.cost_model,
            self._sieve_max_count,
            total_routed_tokens=self.cfg.n_slots
            * self.lm.arch.moe.top_k,
        )
        if gpu_only:
            blocked = np.full(
                state.pim_time_by_count.shape, _PIM_BLOCKED_TIME, np.float32
            )
            blocked[0] = 0.0  # a 0-token expert still costs nothing
            state = state._replace(pim_time_by_count=blocked)
        self._sieve_state = jax.device_put(state)
        self._sieve_version = self.cost_table.version
        self._sieve_gpu_only = gpu_only
        self.sieve_refreshes.append(step)
        # donate the stale state: its device buffers can never be read
        # again (the engine always passes the current state), so free
        # them eagerly instead of waiting for GC — long-lived engines
        # otherwise hold two table exports alive per refresh.
        if stale is not None:
            for leaf in jax.tree.leaves(stale):
                if isinstance(leaf, jax.Array) and not leaf.is_deleted():
                    leaf.delete()

    # ------------------------------------------------------------------
    def _prefill_chunk_impl(self, params, batch, cache, slot: int):
        """Prefill one request's chunk into its slot (B=1 path).

        For simplicity the chunk is the whole prompt (chunked continuation
        uses the same mechanism with q_offset bookkeeping at the engine
        level)."""
        block_ids = batch.pop("block_ids", None)  # paged: slot's block-table row
        logits, req_cache, aux = self.lm.prefill(params, batch)

        if block_ids is None:

            def insert(slot_leaf, req_leaf):
                # slot_leaf: (L, B_slots, T, ...); req_leaf: (L, 1, P, ...)
                start = (0, slot, 0) + (0,) * (slot_leaf.ndim - 3)
                return jax.lax.dynamic_update_slice(
                    slot_leaf, req_leaf.astype(slot_leaf.dtype), start
                )

        else:
            page = self.paged.page

            def insert(pool_leaf, req_leaf):
                # pool_leaf: (L, n_pool, page, ...); req_leaf: (L, 1, P, ...)
                # pad the prompt's KV rows to whole pages and scatter them
                # over the slot's allocated blocks (nbp is trace-static:
                # the prompt length is already a jit key for prefill)
                L, _, P = req_leaf.shape[:3]
                nbp = -(-P // page)
                rows = req_leaf[:, 0]
                pad = nbp * page - P
                if pad:
                    rows = jnp.pad(
                        rows, ((0, 0), (0, pad)) + ((0, 0),) * (rows.ndim - 2)
                    )
                pages = rows.reshape((L, nbp, page) + rows.shape[2:])
                return pool_leaf.at[:, block_ids[:nbp]].set(
                    pages.astype(pool_leaf.dtype)
                )

        new_cache = jax.tree.map(insert, cache, req_cache)
        return logits, new_cache, aux

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Enqueue ``req``; returns False when admission refused it
        (brownout stage 3 sheds the batch tier at the door)."""
        if len(req.prompt) > self.cfg.max_seq:
            raise ValueError(
                f"prompt length {len(req.prompt)} exceeds the KV capacity "
                f"max_seq={self.cfg.max_seq}; raise BatchingConfig.max_seq "
                "or truncate the prompt"
            )
        if req.priority == "batch":
            if self.brownout_stage >= 3:
                self.stats.shed_requests += 1
                if self.tel.enabled:
                    self.tel.counter("engine/shed_requests")
                return False
            if self.brownout_stage >= 1:
                # degrade, don't refuse: the batch tier keeps flowing but
                # each request's decode budget is clamped
                req.max_new_tokens = min(
                    req.max_new_tokens, self.brownout_batch_max_new
                )
        self.sched.submit(req)
        return True

    def set_brownout_stage(self, stage: int) -> None:
        """Adopt a cluster-level brownout stage (idempotent).

        Stage 2+ immediately re-exports the sieve state GPU-only through
        the fixed-shape refresh path — same compiled step, zero jit-cache
        misses — shifting expert work off the PIM stack while the cluster
        is saturated; dropping back below 2 restores the table-driven
        split at the same cost.
        """
        stage = max(int(stage), 0)
        if stage == self.brownout_stage:
            return
        self.brownout_stage = stage
        if self.uses_cost_split:
            self._refresh_sieve_state(
                step=self.stats.steps,
                gpu_only=(stage >= 2) or not self.pim_healthy,
            )
        if self.tel.enabled:
            self.tel.gauge("engine/brownout_stage", float(stage))

    def _run_sieve(self, counts_per_layer: np.ndarray) -> None:
        """Host-side scheduler pass over this step's per-layer counts."""
        kw = {}
        if self.policy in ("dual_threshold", "dual_cost"):
            # the host decision trail must evaluate the same feasibility
            # window as the compiled step's in-graph split
            moe = self.lm.arch.moe
            kw = {
                "tail_tokens": moe.dual_tail_tokens,
                "max_head": moe.dual_max_head,
            }
        measured = self.cost_source == "measured"
        quarantined = (
            measured
            and self._timing_feed is not None
            and self._timing_feed.quarantined
        )
        tel = self.tel
        for li, counts in enumerate(counts_per_layer):
            part = schedule(
                self.policy, counts, self.cost_model, self.cost_table, **kw
            )
            if measured:
                # queue the tail set's token counts for the refresh-cadence
                # probe pass — the DRAM proxy is never consulted here.
                # Probing continues even under quarantine: the raw
                # measurements are what the health monitor needs to see
                # the fault clear.
                for e in part.pim_experts:
                    n = int(counts[e])
                    if n > 0:
                        self._pending_tail_counts.add(n)
                self._last_head_counts = [
                    int(counts[e]) for e in part.gpu_experts if counts[e] > 0
                ]
                if quarantined:
                    # graceful degradation: the measured feed is untrusted,
                    # so the table falls back to the roofline model proxy
                    # (its own fallback estimator) until clearance re-warms
                    # the measured path
                    for e in part.pim_experts:
                        n = int(counts[e])
                        if n > 0:
                            self.cost_table.update(
                                n, self.cost_model.t_pim_gemv_roofline(n)
                            )
            elif self._pim is not None:
                # observe "PIM" execution times for the chosen set (from
                # the DRAM-timing model — the synthetic-oracle fallback)
                for e in part.pim_experts:
                    n = int(counts[e])
                    if n > 0:
                        self.cost_table.update(
                            n, self._pim.expert_time(self.layer_spec, n)
                        )
            if tel.enabled:
                while len(self._layer_metric_names) <= li:
                    j = len(self._layer_metric_names)
                    self._layer_metric_names.append(
                        (f"expert_tokens/layer{j}", f"head_mass/layer{j}")
                    )
                hist_name, mass_name = self._layer_metric_names[li]
                routed = counts[counts > 0]
                total = int(routed.sum())
                tel.observe(hist_name, routed)
                if total > 0:
                    # bimodality gauge: fraction of routed mass on the
                    # chosen head (grouped-GEMM) set at this step's split
                    gpu = np.asarray(part.gpu_experts, dtype=np.int64)
                    head_mass = (
                        float(counts[gpu].sum()) / total if gpu.size else 0.0
                    )
                    tel.gauge(mass_name, head_mass)
            self.stats.partitions.append(
                {
                    "step": self.stats.steps,
                    "layer": li,
                    "n_gpu": len(part.gpu_experts),
                    "n_pim": len(part.pim_experts),
                    "t_total_est": part.t_total,
                }
            )

    def _run_probes(self) -> None:
        """Refresh-cadence stage probes: measure the queued tail counts
        (the CostTable cells the split decides on) plus one head / dispatch
        / attention cell shaped like the last decode batch.  Off the
        critical path by construction — runs only at refresh boundaries."""
        moe = self.lm.arch.moe
        tails = sorted(self._pending_tail_counts)
        self._pending_tail_counts.clear()
        if len(tails) > _MAX_TAIL_PROBES:
            # sample evenly across the sorted counts so the probe budget
            # still covers the whole observed range
            idx = np.unique(
                np.linspace(0, len(tails) - 1, _MAX_TAIL_PROBES)
                .round()
                .astype(int)
            )
            tails = [tails[i] for i in idx]
        for n in tails:
            self._probes.tail(n)
        for _ in range(_SENTINEL_PROBES - tails.count(_SENTINEL_TAIL)):
            self._probes.tail(_SENTINEL_TAIL)
        if self._last_head_counts:
            self._probes.head(self._last_head_counts)
            self._last_head_counts = []
        if self._last_decode_batch:
            self._probes.dispatch(
                self._last_decode_batch, moe.n_experts, moe.top_k
            )
            self._probes.attention(self._last_decode_batch, self._last_kv_depth)

    def _update_pim_health(self, step: int) -> None:
        """Boundary-cadence health pass over the measured cost loop.

        Two orthogonal detectors feed one gate:

        * **drift** — the sentinel tail cell's measured time vs the
          roofline model proxy.  The ratio is stationary while healthy
          (the EMA baseline absorbs the constant hardware/model scale),
          so a breach means the PIM-side stage genuinely slowed — the
          brownout signature;
        * **staleness** — the feed's accepted-poll counter.  A feed whose
          samples all fail validity/outlier filters stops advancing it
          even though no observation ever "looked wrong" — the poisoned-
          probe signature.

        Either flag quarantines the feed (model-proxy fallback) and
        clamps the next sieve export to GPU-only; clearance (with the
        monitor's hysteresis) re-warms the measured path.
        """
        mon, feed = self.health, self._timing_feed
        if mon is None or feed is None:
            return
        t = float(step)
        raw = feed.last_raw.get(_SENTINEL_TAIL)
        if raw is not None and self._roofline_t1 > 0:
            mon.observe("pim", raw / self._roofline_t1, t=t)
        mon.watch("cost_feed", float(feed.n_ok), t=t)
        healthy = mon.is_healthy("pim") and mon.is_healthy("cost_feed")
        if healthy != self.pim_healthy:
            self.pim_healthy = healthy
            feed.quarantined = not healthy
            if healthy:
                # accept the first measured window ungated: quarantine may
                # have re-seeded the table at the proxy's scale
                feed.rewarm()
        if self.tel.enabled:
            self.tel.gauge(
                "engine/pim_healthy", 1.0 if self.pim_healthy else 0.0
            )

    def step(self) -> List[Request]:
        """One engine step: admit -> prefill work -> decode -> retire."""
        t0 = time.perf_counter()
        tel = self.tel
        step_span = tel.span("engine/step", value=float(self.stats.steps))
        step_span.__enter__()
        with tel.span("engine/admit"):
            # queued requests past their service-start deadline leave
            # loudly before slot assignment — they never held KV
            expired = self.sched.expire_queue(t0)
            for r in expired:
                r.finish_time = t0
                self.sched.finished.append(r)
                self.stats.expired_requests += 1
            if expired and tel.enabled:
                tel.counter("engine/expired_requests", len(expired))
            self.sched.admit()

        # ---- prefill ----
        for req in self.sched.prefill_work():
            prompt = np.asarray(req.prompt, np.int32)[None, :]
            batch = {"tokens": jnp.asarray(prompt)}
            if self.paged is not None:
                # allocate the prompt's blocks up front; the scatter in
                # _prefill_chunk_impl writes through this block-table row
                self.paged.ensure(req.slot, len(req.prompt))
                batch["block_ids"] = jnp.asarray(
                    self.paged.block_table[req.slot]
                )
            if self.uses_cost_split:
                batch["sieve"] = self._sieve_state
            if self.lm.arch.family == "vlm":
                P = prompt.shape[1]
                pos = jnp.broadcast_to(jnp.arange(P), (1, P))
                batch["mrope_positions"] = jnp.stack([pos, pos, pos])
            with tel.span("engine/prefill", value=float(len(req.prompt))):
                logits, self.cache, p_aux = self._prefill_chunk(
                    self.params, batch, self.cache, req.slot
                )
                logits = np.asarray(logits)
            if self.is_moe:
                self.stats.dropped_tokens += int(p_aux.dropped)
                self.stats.routed_tokens += int(np.asarray(p_aux.counts).sum())
            req.prefill_done = len(req.prompt)
            self.stats.prefill_tokens += len(req.prompt)
            tok = self._sample(logits[:, -1])
            req.generated.append(int(tok[0]))
            if req.first_token_time is None:
                req.first_token_time = time.perf_counter()

        # ---- decode ----
        batch_reqs = self.sched.decode_batch()
        if batch_reqs:
            B = self.cfg.n_slots
            tokens = np.zeros((B, 1), np.int32)
            position = np.zeros((B,), np.int32)
            for r in batch_reqs:
                tokens[r.slot, 0] = (
                    r.generated[-1] if r.generated else r.prompt[-1]
                )
                # KV-write position of the token being fed: generated[-1]
                # was sampled but not yet written, so it lands one before
                # the request's next-write cursor.
                position[r.slot] = r.position - 1 if r.generated else r.position
            db = {"tokens": jnp.asarray(tokens), "position": jnp.asarray(position)}
            if self.paged is not None:
                # grow block lists to cover this step's KV write, then ship
                # the (fixed-shape) indexing state with the batch — same
                # jit signature every step, zero added cache misses
                for r in batch_reqs:
                    self.paged.ensure(r.slot, int(position[r.slot]) + 1)
                db["block_tables"] = jnp.asarray(self.paged.block_table)
                db["pool_owner"] = jnp.asarray(self.paged.owner)
                db["pool_pos"] = jnp.asarray(self.paged.block_pos)
            if self.uses_cost_split:
                db["sieve"] = self._sieve_state
            if self.lm.arch.family == "vlm":
                mp = jnp.asarray(position)[None, :, None]
                db["mrope_positions"] = jnp.concatenate([mp, mp, mp], axis=0)
            with tel.span("engine/decode", value=float(len(batch_reqs))):
                logits, self.cache, aux = self._decode(self.params, db, self.cache)
                logits = np.asarray(logits)
            toks = self._sample(logits[:, 0])
            for r in batch_reqs:
                r.generated.append(int(toks[r.slot]))
                self.stats.decode_tokens += 1
            if self.is_moe:
                self.stats.dropped_tokens += int(aux.dropped)
                self.stats.routed_tokens += int(np.asarray(aux.counts).sum())
            self._last_decode_batch = len(batch_reqs)
            self._last_kv_depth = int(position.max()) + 1
            if self.is_moe and aux.counts.shape[0] > 0:
                with tel.span("engine/sieve_host"):
                    self._run_sieve(np.asarray(aux.counts))

        # measured cost loop + cost-table refresh cadence: the in-graph
        # split only ever changes at these boundaries (stale-table
        # semantics between them)
        boundary = (self.stats.steps + 1) % self.sieve_refresh_every == 0
        if boundary and self._probes is not None:
            with tel.span("engine/probe"):
                self._run_probes()
                self._timing_feed.poll()
            self._update_pim_health(self.stats.steps + 1)
        if boundary and self.uses_cost_split:
            with tel.span("engine/sieve_refresh"):
                self._refresh_sieve_state(
                    step=self.stats.steps + 1,
                    gpu_only=not self.pim_healthy
                    or self.brownout_stage >= 2,
                )

        # KV-capacity cap: the next decode feed writes KV at
        # ``r.position - 1``; once that reaches max_seq the dense
        # dynamic_update_slice would clamp and silently overwrite the last
        # entry (and the paged path would write past its last block) —
        # finish the request loudly instead.
        for r in self.sched.active:
            if (
                r.generated
                and not r.done
                and r.position - 1 >= self.cfg.max_seq
            ):
                r.truncated = True
                self.stats.truncated_requests += 1

        done = self.sched.retire(time.perf_counter())
        if self.paged is not None:
            for r in done:
                self.paged.free_slot(r.slot)
        # deadline-expired queue entries are terminal too — surface them
        # to the caller after the paged free loop (they never held a slot)
        done = expired + done
        self.stats.steps += 1
        self.stats.wall_time += time.perf_counter() - t0
        if tel.enabled:
            # KV occupancy: fraction of the slot pool's cells holding live
            # KV entries (sum of per-request write cursors / total cells)
            occ = sum(r.position for r in self.sched.active) / float(
                self.cfg.n_slots * self.cfg.max_seq
            )
            tel.gauge("engine/kv_occupancy", occ)
            if self.paged is not None:
                # fraction of allocatable pool blocks currently owned
                tel.gauge(
                    "engine/kv_pool_used",
                    1.0 - self.paged.n_free / max(self.paged.n_pool - 1, 1),
                )
            tel.gauge(
                "engine/batch_occupancy",
                len(batch_reqs) / max(self.cfg.n_slots, 1),
            )
            tel.gauge("engine/drop_rate", self.stats.drop_rate)
            # jit-cache growth since last step = compile misses this step
            n_entries = self._decode._cache_size() + self._prefill_chunk._cache_size()
            if n_entries > self._jit_cache_seen:
                tel.counter(
                    "engine/jit_cache_miss", n_entries - self._jit_cache_seen
                )
                self._jit_cache_seen = n_entries
        step_span.__exit__(None, None, None)
        return done

    # ------------------------------------------------------------------
    def snapshot(
        self, snap_dir: str, snap_id: Optional[int] = None,
        keep: Optional[int] = None,
    ) -> str:
        """Atomic, checksummed snapshot of the engine's runtime state
        (KV cache + slots, SieveState, cost table, RNG, requests, feed and
        health monitors).  See :mod:`repro.recovery.snapshot`."""
        from repro.recovery.snapshot import save_engine_snapshot

        return save_engine_snapshot(self, snap_dir, snap_id=snap_id, keep=keep)

    def restore(self, snap_dir: str, snap_id: Optional[int] = None) -> int:
        """Restore from a snapshot (newest committed by default, walking
        back past corrupt ones); continues bit-identically — same tokens,
        same splits, zero added jit-cache misses (pinned by
        tests/test_recovery.py).  Returns the snap id restored."""
        from repro.recovery.snapshot import restore_engine_snapshot

        return restore_engine_snapshot(self, snap_dir, snap_id=snap_id)

    def run_until_done(self, max_steps: int = 10_000) -> List[Request]:
        for _ in range(max_steps):
            if self.sched.idle:
                break
            self.step()
        return self.sched.finished

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        if self.greedy:
            return logits.argmax(-1)
        z = logits - logits.max(-1, keepdims=True)
        p = np.exp(z) / np.exp(z).sum(-1, keepdims=True)
        return np.array(
            [self.rng.choice(p.shape[-1], p=p[i]) for i in range(p.shape[0])]
        )
