"""Continuous batching policies (paper §7.1 / §7.3).

``SlotScheduler`` manages a fixed pool of KV-cache slots: admits queued
requests into free slots, runs prefill (whole-prompt for disaggregated-PD
style, or chunked for colocated PD with a per-step prefill token budget —
vLLM-style "at most two prefill requests per batch", §7.3), and retires
finished requests.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Any, Deque, Dict, List, Optional

import numpy as np

from .request import Request


@dataclass
class BatchingConfig:
    n_slots: int = 8
    max_seq: int = 512
    colocated_pd: bool = False
    prefill_chunk: int = 128  # tokens of prefill work per engine step
    max_prefills_per_step: int = 2
    # paged KV cache: slots index a shared block pool through a
    # (n_slots, max_blocks) block table instead of owning a dense
    # (max_seq, ...) buffer.  Physical block 0 is reserved as the trash
    # block every unused table cell points at.
    paged: bool = False
    page_size: int = 16
    pool_blocks: Optional[int] = None  # default: no-evict worst case + trash

    @property
    def blocks_per_slot(self) -> int:
        return -(-self.max_seq // self.page_size)

    def resolved_pool_blocks(self) -> int:
        if self.pool_blocks is not None:
            return int(self.pool_blocks)
        return self.n_slots * self.blocks_per_slot + 1


class PagedKVCache:
    """Host-side block-table allocator for the shared KV block pool.

    The device side is a pair of ``(n_layers, n_pool, page, Kv, dh)``
    pools (``LM.init_paged_cache``); this class owns the int32 indexing
    state shipped with each decode batch:

    * ``block_table`` (n_slots, max_blocks) — logical → physical block per
      slot; unused cells hold ``TRASH`` (physical block 0, owner -1,
      never allocated) so the batch-wide masked KV write of an idle slot
      lands harmlessly.
    * ``owner`` (n_pool,) — slot owning each physical block, -1 if free.
    * ``block_pos`` (n_pool,) — the block's logical index within its
      owner (drives the position arithmetic of the pool-major XLA twin).

    Invariant (pinned by a hypothesis property test): free blocks +
    allocated blocks == n_pool - 1, with every allocated block owned by
    exactly one (slot, logical) cell.
    """

    TRASH = 0

    def __init__(self, cfg: BatchingConfig):
        self.page = cfg.page_size
        self.n_slots = cfg.n_slots
        self.max_blocks = cfg.blocks_per_slot
        self.n_pool = cfg.resolved_pool_blocks()
        if self.n_pool < 2:
            raise ValueError("pool_blocks must be >= 2 (trash block + 1)")
        self.block_table = np.full(
            (self.n_slots, self.max_blocks), self.TRASH, np.int32
        )
        self.owner = np.full((self.n_pool,), -1, np.int32)
        self.block_pos = np.zeros((self.n_pool,), np.int32)
        # LIFO free stack, low blocks handed out first
        self.free_blocks: List[int] = list(range(self.n_pool - 1, 0, -1))
        self.slot_blocks = np.zeros((self.n_slots,), np.int32)

    @property
    def n_free(self) -> int:
        return len(self.free_blocks)

    def _alloc_block(self, slot: int, logical: int) -> int:
        if not self.free_blocks:
            raise RuntimeError(
                f"paged KV pool exhausted (pool_blocks={self.n_pool}, "
                f"slot {slot} needs logical block {logical}); size "
                "BatchingConfig.pool_blocks for the live working set"
            )
        b = self.free_blocks.pop()
        self.block_table[slot, logical] = b
        self.owner[b] = slot
        self.block_pos[b] = logical
        return b

    def ensure(self, slot: int, n_tokens: int) -> None:
        """Grow ``slot``'s block list to cover ``n_tokens`` KV entries."""
        need = min(-(-max(int(n_tokens), 0) // self.page), self.max_blocks)
        while int(self.slot_blocks[slot]) < need:
            self._alloc_block(slot, int(self.slot_blocks[slot]))
            self.slot_blocks[slot] += 1

    def free_slot(self, slot: int) -> None:
        """Return all of ``slot``'s blocks to the pool (request retired).
        The device pool keeps the stale K/V bytes — positions past a new
        owner's length are masked by the kernels, never read."""
        for j in range(int(self.slot_blocks[slot])):
            b = int(self.block_table[slot, j])
            self.owner[b] = -1
            self.block_pos[b] = 0
            self.free_blocks.append(b)
            self.block_table[slot, j] = self.TRASH
        self.slot_blocks[slot] = 0

    # ---- snapshot (de)serialization ----------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {
            "page": self.page,
            "n_pool": self.n_pool,
            "block_table": self.block_table.tolist(),
            "owner": self.owner.tolist(),
            "block_pos": self.block_pos.tolist(),
            "free_blocks": list(self.free_blocks),
            "slot_blocks": self.slot_blocks.tolist(),
        }

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        if int(d["page"]) != self.page or int(d["n_pool"]) != self.n_pool:
            raise ValueError(
                "paged KV geometry mismatch: snapshot "
                f"(page={d['page']}, n_pool={d['n_pool']}) vs engine "
                f"(page={self.page}, n_pool={self.n_pool})"
            )
        self.block_table = np.asarray(d["block_table"], np.int32)
        self.owner = np.asarray(d["owner"], np.int32)
        self.block_pos = np.asarray(d["block_pos"], np.int32)
        self.free_blocks = [int(b) for b in d["free_blocks"]]
        self.slot_blocks = np.asarray(d["slot_blocks"], np.int32)


class SlotScheduler:
    def __init__(self, cfg: BatchingConfig):
        self.cfg = cfg
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * cfg.n_slots
        self.finished: List[Request] = []
        self._sub_seq = 0  # submission order — the EDF admit tie-break

    def submit(self, req: Request) -> None:
        req._sub_seq = self._sub_seq
        self._sub_seq += 1
        self.queue.append(req)

    @property
    def active(self) -> List[Request]:
        return [r for r in self.slots if r is not None]

    @property
    def idle(self) -> bool:
        return not self.queue and not self.active

    def expire_queue(self, now: float) -> List[Request]:
        """Remove queued requests whose service-start deadline has passed
        (marked ``expired`` — a terminal outcome, counted by the engine)."""
        expired = [
            r for r in self.queue if r.deadline is not None and r.deadline <= now
        ]
        for r in expired:
            self.queue.remove(r)
            r.expired = True
        return expired

    def admit(self) -> List[Request]:
        """Move queued requests into free slots; returns newly admitted.

        Selection is priority-aware EDF: interactive before batch,
        earliest deadline first, FIFO submission order as the tie-break —
        so default traffic (one class, no deadlines) admits in exactly
        the historical FIFO order.
        """
        admitted = []
        for i, r in enumerate(self.slots):
            if r is None and self.queue:
                req = min(
                    self.queue,
                    key=lambda q: (
                        0 if q.priority == "interactive" else 1,
                        q.deadline if q.deadline is not None else float("inf"),
                        getattr(q, "_sub_seq", q.req_id),
                    ),
                )
                self.queue.remove(req)
                req.slot = i
                self.slots[i] = req
                admitted.append(req)
        return admitted

    def prefill_work(self) -> List[Request]:
        """Requests owed prefill this step (colocated: bounded chunk count)."""
        pending = [
            r for r in self.active if r.prefill_done < len(r.prompt)
        ]
        if not self.cfg.colocated_pd:
            return pending  # disaggregated: prefill fully before decoding
        return pending[: self.cfg.max_prefills_per_step]

    def decode_batch(self) -> List[Request]:
        return [
            r
            for r in self.active
            if r.prefill_done >= len(r.prompt) and not r.done
        ]

    def retire(self, now: float) -> List[Request]:
        out = []
        for i, r in enumerate(self.slots):
            if r is not None and r.done:
                r.finish_time = now
                self.finished.append(r)
                self.slots[i] = None
                out.append(r)
        return out
