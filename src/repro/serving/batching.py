"""Continuous batching policies (paper §7.1 / §7.3).

``SlotScheduler`` manages a fixed pool of KV-cache slots: admits queued
requests into free slots, runs prefill (whole-prompt for disaggregated-PD
style, or chunked for colocated PD with a per-step prefill token budget —
vLLM-style "at most two prefill requests per batch", §7.3), and retires
finished requests.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, List, Optional

from .request import Request


@dataclass
class BatchingConfig:
    n_slots: int = 8
    max_seq: int = 512
    colocated_pd: bool = False
    prefill_chunk: int = 128  # tokens of prefill work per engine step
    max_prefills_per_step: int = 2


class SlotScheduler:
    def __init__(self, cfg: BatchingConfig):
        self.cfg = cfg
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * cfg.n_slots
        self.finished: List[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    @property
    def active(self) -> List[Request]:
        return [r for r in self.slots if r is not None]

    @property
    def idle(self) -> bool:
        return not self.queue and not self.active

    def admit(self) -> List[Request]:
        """Move queued requests into free slots; returns newly admitted."""
        admitted = []
        for i, r in enumerate(self.slots):
            if r is None and self.queue:
                req = self.queue.popleft()
                req.slot = i
                self.slots[i] = req
                admitted.append(req)
        return admitted

    def prefill_work(self) -> List[Request]:
        """Requests owed prefill this step (colocated: bounded chunk count)."""
        pending = [
            r for r in self.active if r.prefill_done < len(r.prompt)
        ]
        if not self.cfg.colocated_pd:
            return pending  # disaggregated: prefill fully before decoding
        return pending[: self.cfg.max_prefills_per_step]

    def decode_batch(self) -> List[Request]:
        return [
            r
            for r in self.active
            if r.prefill_done >= len(r.prompt) and not r.done
        ]

    def retire(self, now: float) -> List[Request]:
        out = []
        for i, r in enumerate(self.slots):
            if r is not None and r.done:
                r.finish_time = now
                self.finished.append(r)
                self.slots[i] = None
                out.append(r)
        return out
