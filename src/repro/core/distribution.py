"""Token-to-expert distribution analytics (paper §3, Figs 1/3/5, Obs 1-4).

Everything here operates on an *assignment matrix* or a per-expert token
count vector for one MoE layer and one batch:

    counts[e] = number of tokens routed to expert e   (0 <= counts[e] <= B*k)

The paper's bins (Fig 5): GEMV experts (N == 1), skinny GEMM (2 <= N <= 4,
split N == 2 and 3 <= N <= 4), GEMM (N > 4).  "These bins are used only to
expose arithmetic disparity; they are not Sieve scheduling thresholds."
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Sequence

import numpy as np

BIN_EDGES = ("N=1", "N=2", "3<=N<=4", "N>4")


def counts_from_assignments(assignments: np.ndarray, n_experts: int) -> np.ndarray:
    """``assignments``: (tokens, top_k) int expert ids -> per-expert counts."""
    return np.bincount(np.asarray(assignments).ravel(), minlength=n_experts)


def expert_bins(counts: Sequence[int]) -> Dict[str, float]:
    """Fraction of *activated* expert computations per arithmetic-intensity
    bin (paper Fig 5 normalizes over activated experts)."""
    c = np.asarray(counts)
    c = c[c > 0]
    n = max(len(c), 1)
    return {
        "N=1": float((c == 1).sum()) / n,
        "N=2": float((c == 2).sum()) / n,
        "3<=N<=4": float(((c >= 3) & (c <= 4)).sum()) / n,
        "N>4": float((c > 4).sum()) / n,
    }


def gemv_fraction(counts: Sequence[int]) -> float:
    """Fraction of activated experts that degenerate to pure GEMV (Obs 4)."""
    return expert_bins(counts)["N=1"]


def memory_bound_fraction(counts: Sequence[int]) -> float:
    """GEMV + skinny-GEMM fraction (N <= 4), paper Obs 3."""
    b = expert_bins(counts)
    return b["N=1"] + b["N=2"] + b["3<=N<=4"]


@dataclass(frozen=True)
class ModelParamSplit:
    """Parameter accounting for act-ratio (paper Fig 3)."""

    always_active_params: float  # attention, norms, embeddings, shared experts
    params_per_expert: float
    n_experts: int

    @property
    def total_params(self) -> float:
        return self.always_active_params + self.params_per_expert * self.n_experts


def act_ratio(counts: Sequence[int], split: ModelParamSplit) -> float:
    """Activated-parameter ratio for one batch (paper Fig 3).

    Parameters in non-MoE layers are always activated and included.
    """
    c = np.asarray(counts)
    n_activated = int((c > 0).sum())
    activated = split.always_active_params + split.params_per_expert * n_activated
    return activated / split.total_params


def arithmetic_intensity(
    n_tokens: int, d_model: int, d_ff: int, n_matrices: int = 3, dtype_bytes: int = 2
) -> float:
    """FLOPs per byte for an expert FFN visited by ``n_tokens`` tokens.

    Weights are read once regardless of N; activations are O(N).  This is
    the quantity plotted on the roofline x-axis in paper Fig 4.
    """
    flops = 2.0 * n_tokens * n_matrices * d_model * d_ff
    weight_bytes = n_matrices * d_model * d_ff * dtype_bytes
    act_bytes = 2.0 * n_tokens * d_model * dtype_bytes
    return flops / (weight_bytes + act_bytes)


def bimodality_coefficient(counts: Sequence[int]) -> float:
    """Sarle's bimodality coefficient over activated-expert token counts.

    > 5/9 (~0.555) suggests bimodality.  Used in tests/benchmarks to
    quantify "increasingly bimodal" (paper §1/§3) numerically.
    """
    c = np.asarray(counts, dtype=np.float64)
    c = c[c > 0]
    n = len(c)
    if n < 4:
        return float("nan")
    m = c.mean()
    s = c.std(ddof=1)
    if s == 0:
        return float("nan")
    g1 = ((c - m) ** 3).mean() / (c.std(ddof=0) ** 3)  # skewness
    g2 = ((c - m) ** 4).mean() / (c.std(ddof=0) ** 4) - 3.0  # excess kurtosis
    return (g1**2 + 1.0) / (g2 + 3.0 * (n - 1) ** 2 / ((n - 2) * (n - 3)))


def distribution_summary(counts: Sequence[int]) -> Dict[str, float]:
    c = np.asarray(counts)
    act = c[c > 0]
    return {
        "n_experts": int(len(c)),
        "n_activated": int(len(act)),
        "max_count": int(act.max()) if len(act) else 0,
        "mean_count": float(act.mean()) if len(act) else 0.0,
        "gemv_fraction": gemv_fraction(c),
        "memory_bound_fraction": memory_bound_fraction(c),
        "bimodality": bimodality_coefficient(c),
        **expert_bins(c),
    }
