"""Sieve core: the paper's contribution (scheduler + runtime coordination).

Shared between the cycle-approximate simulator (:mod:`repro.sim`) and the
JAX/TPU serving runtime (:mod:`repro.serving`, :mod:`repro.models.moe`).
"""

from .cost_model import (  # noqa: F401
    AttnLayerSpec,
    CostModel,
    DRAMTiming,
    MoELayerSpec,
    PIMSpec,
    SystemSpec,
    XPUSpec,
    attention_time_on_pim,
    attention_time_on_xpu,
    b200_pim_system,
    tpu_v5e_system,
    B200,
    HBM_PIM,
    TPU_V5E,
)
from .cost_table import CostTable, make_roofline_fallback  # noqa: F401
from .dag import Dag, build_moe_layer_dag  # noqa: F401
from .distribution import (  # noqa: F401
    ModelParamSplit,
    act_ratio,
    arithmetic_intensity,
    bimodality_coefficient,
    counts_from_assignments,
    distribution_summary,
    expert_bins,
    gemv_fraction,
    memory_bound_fraction,
)
from .overlap import CompiledDag, Schedule, chain_layers, list_schedule  # noqa: F401
from .scheduler import (  # noqa: F401
    POLICIES,
    Partition,
    allexp_schedule,
    brute_force_schedule,
    dual_cost_schedule,
    dual_cost_schedule_reference,
    dual_threshold_schedule,
    gpu_only_schedule,
    noexp_schedule,
    pimoe_schedule,
    pimoe_schedule_reference,
    schedule,
    sieve_schedule,
    sieve_schedule_reference,
)
