"""Hardware specs and analytic timing estimators for the Sieve scheduler.

Implements the lightweight timing models of paper §5.1:

    T_total = max(T_Comm, T_GPU(G), T_PIM(S))
    T_GPU(G) = max(T_offchip(G), T_comp(G))

The estimates here are deliberately cheap (the scheduler sits on the
critical path, §5.1 "we prioritize lightweight estimates over precise
modeling").  Detailed execution times come from the cycle-approximate
simulator in ``repro.sim``, which feeds observed PIM GEMV timings back
into the :class:`repro.core.cost_table.CostTable`.

Units: seconds, bytes, FLOPs throughout.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Hardware descriptions
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DRAMTiming:
    """HBM3E timing parameters (paper Table 1), in cycles @ tCK seconds."""

    tCK: float = 0.50e-9  # 8.0 Gbps pin → 0.5 ns cycle
    tRCD: int = 28
    tRP: int = 28
    tRAS: int = 68
    tRC: int = 96
    tCL: int = 28
    tWR: int = 32
    tCCD_S: int = 2
    tCCD_L: int = 4
    tRRD_S: int = 6
    tRRD_L: int = 6
    tFAW: int = 12
    tREFI: float = 3900e-9  # seconds
    tRFC: float = 400e-9  # seconds

    def seconds(self, cycles: float) -> float:
        return cycles * self.tCK

    @property
    def refresh_overhead(self) -> float:
        """Fraction of time the DRAM is unavailable due to refresh."""
        return self.tRFC / self.tREFI


@dataclass(frozen=True)
class XPUSpec:
    """A host accelerator: B200 GPU for the paper, TPU v5e for this repo."""

    name: str
    peak_flops: float  # at serving dtype (bf16/fp16)
    hbm_bw: float  # external HBM bandwidth, bytes/s
    hbm_capacity: float  # bytes
    link_bw: float  # inter-device bandwidth per direction, bytes/s
    link_latency: float  # seconds
    # Matmul engines operate on fixed tiles; rows are padded up to tile_m.
    tile_m: int = 128

    def gemm_time(self, flops: float) -> float:
        return flops / self.peak_flops

    def padded_rows(self, n_rows: int) -> int:
        t = self.tile_m
        return int(-(-n_rows // t) * t) if n_rows > 0 else 0


@dataclass(frozen=True)
class PIMSpec:
    """HBM-PIM stack description (paper Table 1, Samsung HBM-PIM style)."""

    stacks: int = 8
    pseudo_channels_per_stack: int = 32
    banks_per_channel: int = 24
    page_bytes: int = 1024
    pin_rate_gbps: float = 8.0
    compute_density: float = 1.0  # ops per byte streamed internally
    # Internal (near-bank) bandwidth exceeds the external pin bandwidth by
    # roughly this factor in commercial HBM-PIM (paper §2.2: "an order of
    # magnitude"; Samsung Aquabolt-XL achieves ~4x sustained for GEMV).
    internal_bw_multiplier: float = 4.0
    timing: DRAMTiming = dataclasses.field(default_factory=DRAMTiming)
    # Fixed per-GEMV command overhead: GWRITE broadcast of the input vector
    # to every channel's global buffer + result readback over the external
    # bus + command issue gaps (paper §6.2 sub-steps (i)-(iii)).
    gemv_cmd_overhead: float = 0.35e-6

    @property
    def n_channels(self) -> int:
        return self.stacks * self.pseudo_channels_per_stack

    @property
    def external_bw(self) -> float:
        """External HBM bandwidth implied by the pin rate (bytes/s)."""
        # 1024 DQ pins per stack (HBM3E) at pin_rate.
        return self.stacks * 1024 * self.pin_rate_gbps * 1e9 / 8

    @property
    def internal_bw(self) -> float:
        return self.external_bw * self.internal_bw_multiplier

    @property
    def peak_ops(self) -> float:
        """Peak PIM throughput (ops/s) = internal bytes/s x ops/byte."""
        return self.internal_bw * self.compute_density


@dataclass(frozen=True)
class SystemSpec:
    """One device (xPU + optional attached PIM) within a serving system."""

    xpu: XPUSpec
    pim: Optional[PIMSpec]
    n_devices: int = 1

    def replace(self, **kw) -> "SystemSpec":
        return dataclasses.replace(self, **kw)


# Paper Table 1: DGX B200-class GPU with HBM-PIM stacks.
B200 = XPUSpec(
    name="B200",
    peak_flops=2250e12,
    hbm_bw=8.0e12,
    hbm_capacity=96e9,  # 50% of 192 GB sacrificed for PIM PUs (Table 1 note)
    link_bw=900e9,
    link_latency=0.8e-6,
)

HBM_PIM = PIMSpec()

# TPU v5e constants (roofline targets for the JAX framework).
TPU_V5E = XPUSpec(
    name="TPUv5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    hbm_capacity=16e9,
    link_bw=50e9,
    link_latency=1.0e-6,
)


def b200_pim_system(n_devices: int = 1) -> SystemSpec:
    return SystemSpec(xpu=B200, pim=HBM_PIM, n_devices=n_devices)


def tpu_v5e_system(n_devices: int = 1) -> SystemSpec:
    return SystemSpec(xpu=TPU_V5E, pim=None, n_devices=n_devices)


# ---------------------------------------------------------------------------
# Workload descriptions
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoELayerSpec:
    """Dimensions of one MoE layer (all experts share these, paper §3.3)."""

    d_model: int
    d_ff: int  # expert intermediate size
    n_experts: int
    top_k: int
    n_shared: int = 0
    gated: bool = True  # SwiGLU: 3 weight matrices, else 2
    dtype_bytes: int = 2

    @property
    def n_matrices(self) -> int:
        return 3 if self.gated else 2

    @property
    def expert_param_bytes(self) -> int:
        return self.n_matrices * self.d_model * self.d_ff * self.dtype_bytes

    def expert_flops(self, n_tokens: int) -> float:
        return 2.0 * n_tokens * self.n_matrices * self.d_model * self.d_ff

    def token_io_bytes(self, n_tokens: int) -> int:
        # activation in + activation out per expert visit
        return 2 * n_tokens * self.d_model * self.dtype_bytes


@dataclass(frozen=True)
class AttnLayerSpec:
    """Decode-phase attention dims (the op offloaded to PIM, paper §2.2)."""

    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    dtype_bytes: int = 2

    def kv_bytes(self, batch: int, seq: int) -> float:
        return 2.0 * batch * seq * self.n_kv_heads * self.d_head * self.dtype_bytes

    def decode_flops(self, batch: int, seq: int) -> float:
        # q@k^T and p@v per head for one new token.
        return 2.0 * batch * seq * self.n_heads * self.d_head * 2

    def qkvo_param_bytes(self) -> int:
        d, h, kv, dh = self.d_model, self.n_heads, self.n_kv_heads, self.d_head
        return (d * h * dh + 2 * d * kv * dh + h * dh * d) * self.dtype_bytes


# ---------------------------------------------------------------------------
# Cost model (paper §5.1 timing models)
# ---------------------------------------------------------------------------


@dataclass
class CostModel:
    """Analytic T_Comm / T_GPU / T_PIM estimators for one device's MoE layer.

    Parameters
    ----------
    system:     hardware description (xPU + PIM).
    layer:      MoE layer dims.
    ep_degree:  expert-parallel degree (number of devices sharing experts).
    gpu_base_flops / gpu_base_bytes:
        non-expert GPU work in the same stage (QKV gen, o-proj, router,
        norms...).  Paper: "T_comp(G) ... includes all operations except
        decode-phase attention and PIM-side expert computation".
    pim_attn_time:
        decode attention time already committed to PIM in this stage
        (the term PIMoE ignores, §5.2 "Comparison with PIMoE").
    """

    system: SystemSpec
    layer: MoELayerSpec
    ep_degree: int = 1
    gpu_base_flops: float = 0.0
    gpu_base_bytes: float = 0.0
    pim_attn_time: float = 0.0
    grouped_gemm_efficiency: float = 0.85  # achievable fraction of peak
    hbm_efficiency: float = 0.9  # achievable fraction of HBM bandwidth

    # ---- T_Comm ----------------------------------------------------------
    def t_comm(self, total_routed_tokens: int) -> float:
        """All-to-all dispatch + combine across the EP group.

        Independent of the PIM/GPU partition (paper §5.1: tokens are routed
        by the gating result regardless of the partition decision).
        """
        if self.ep_degree <= 1:
            return 0.0
        xpu = self.system.xpu
        remote_frac = 1.0 - 1.0 / self.ep_degree
        bytes_one_way = (
            total_routed_tokens * remote_frac * self.layer.d_model * self.layer.dtype_bytes
        )
        # dispatch + combine, each preceded by the routing-map AllGather (3).
        return 2.0 * (bytes_one_way / xpu.link_bw + xpu.link_latency)

    # ---- T_GPU -----------------------------------------------------------
    def t_gpu_offchip(self, gpu_counts: Sequence[int]) -> float:
        """Weight + activation traffic over external HBM for experts in G."""
        counts = np.asarray(gpu_counts, dtype=np.int64)
        counts = counts[counts > 0]
        n_live = int(counts.size)
        weight_bytes = n_live * self.layer.expert_param_bytes
        act_bytes = self.layer.token_io_bytes(int(counts.sum())) if n_live else 0
        return (weight_bytes + act_bytes + self.gpu_base_bytes) / (
            self.system.xpu.hbm_bw * self.hbm_efficiency
        )

    def t_gpu_comp(self, gpu_counts: Sequence[int]) -> float:
        """Grouped-GEMM compute time; rows pad to the matmul engine tile."""
        xpu = self.system.xpu
        counts = np.asarray(gpu_counts, dtype=np.int64)
        counts = counts[counts > 0]
        padded = ((counts + xpu.tile_m - 1) // xpu.tile_m) * xpu.tile_m
        flops = float(self.layer.expert_flops(int(padded.sum()))) + self.gpu_base_flops
        return flops / (xpu.peak_flops * self.grouped_gemm_efficiency)

    def t_gpu(self, gpu_counts: Sequence[int]) -> float:
        return max(self.t_gpu_offchip(gpu_counts), self.t_gpu_comp(gpu_counts))

    # ---- T_PIM -----------------------------------------------------------
    def t_pim_gemv_roofline(self, n_tokens: int) -> float:
        """Roofline fallback for an expert with ``n_tokens`` serialized GEMVs.

        Paper §5.1: used only until the runtime cost table has an observed
        entry; known to overestimate achievable PIM throughput (i.e.
        underestimate time) by 1.8-4.2x.
        """
        pim = self.system.pim
        if pim is None:
            raise ValueError("system has no PIM")
        flops = self.layer.expert_flops(1)  # one GEMV pass streams the weights
        return n_tokens * flops / pim.peak_ops

    def t_pim_gemv_roofline_vec(self, counts) -> np.ndarray:
        """Vectorized :meth:`t_pim_gemv_roofline` over an int count array.

        Bit-identical per element to the scalar call (same operation order).
        """
        pim = self.system.pim
        if pim is None:
            raise ValueError("system has no PIM")
        c = np.asarray(counts, dtype=np.int64)
        flops = self.layer.expert_flops(1)
        return c.astype(np.float64) * flops / pim.peak_ops

    def t_pim(
        self,
        pim_counts: Sequence[int],
        cost_table=None,
    ) -> float:
        """Attention-on-PIM time + serialized expert GEMV time (paper ③)."""
        counts = [int(c) for c in pim_counts if c > 0]
        if cost_table is not None:
            gemv = sum(cost_table.lookup(c) for c in counts)
        else:
            gemv = sum(self.t_pim_gemv_roofline(c) for c in counts)
        return self.pim_attn_time + gemv

    # ---- batched prefix-split evaluation (vectorized scheduler core) -----
    def pim_gemv_times(self, counts, cost_table=None) -> np.ndarray:
        """Per-expert PIM GEMV seconds for an int count array (zeros -> 0).

        Batched replacement for per-expert ``cost_table.lookup`` /
        ``t_pim_gemv_roofline`` calls; values are bit-identical to the
        scalar path.
        """
        c = np.asarray(counts, dtype=np.int64)
        active = c > 0
        out = np.zeros(c.shape, dtype=np.float64)
        if active.any():
            if cost_table is not None:
                out[active] = cost_table.lookup_vec(c[active])
            else:
                out[active] = self.t_pim_gemv_roofline_vec(c[active])
        return out

    def t_gpu_prefix(self, sorted_counts: np.ndarray) -> np.ndarray:
        """``t_gpu`` for every prefix of ``sorted_counts`` at once.

        ``sorted_counts`` must be the active (>0) token counts sorted
        descending; element ``g`` of the result equals
        ``self.t_gpu(sorted_counts[:g])`` bit-exactly (integer byte/FLOP
        totals are prefix-summed exactly in int64; the float operations then
        mirror the scalar path's order).  O(E) instead of O(E^2).
        """
        xpu = self.system.xpu
        sc = np.asarray(sorted_counts, dtype=np.int64)
        n = sc.shape[0]
        cum_tok = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(sc, out=cum_tok[1:])
        padded = ((sc + xpu.tile_m - 1) // xpu.tile_m) * xpu.tile_m
        cum_pad = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(padded, out=cum_pad[1:])
        cum_live = np.arange(n + 1, dtype=np.int64)

        m = self.layer
        # offchip: n_live * expert_param_bytes + token_io_bytes(total) + base
        traffic = cum_live * m.expert_param_bytes + (
            2 * cum_tok * m.d_model * m.dtype_bytes
        )
        t_offchip = (traffic + self.gpu_base_bytes) / (
            xpu.hbm_bw * self.hbm_efficiency
        )
        # comp: expert_flops(padded total) + base, same operation order as
        # MoELayerSpec.expert_flops (2.0 * n * n_matrices * d_model * d_ff)
        flops = 2.0 * cum_pad * m.n_matrices * m.d_model * m.d_ff
        t_comp = (flops + self.gpu_base_flops) / (
            xpu.peak_flops * self.grouped_gemm_efficiency
        )
        return np.maximum(t_offchip, t_comp)

    def t_pim_suffix(self, sorted_counts: np.ndarray, cost_table=None) -> np.ndarray:
        """``t_pim`` for every suffix of ``sorted_counts`` at once.

        Element ``g`` equals ``self.t_pim(sorted_counts[g:][::-1], ...)``
        bit-exactly: the suffix scan accumulates least-popular-first, the
        same association order a scalar left-to-right sum over the reversed
        suffix uses (floating-point addition commutes but does not
        associate, so the order is pinned on both sides).
        """
        sc = np.asarray(sorted_counts, dtype=np.int64)
        n = sc.shape[0]
        per_expert = self.pim_gemv_times(sc, cost_table)
        out = np.empty(n + 1, dtype=np.float64)
        out[n] = 0.0
        if n:
            # cumsum over the reversed per-expert times: entry j holds
            # ts[n-1] + ... + ts[n-1-j]; suffix split g reads entry n-1-g.
            out[:n] = np.cumsum(per_expert[::-1])[::-1]
        return self.pim_attn_time + out

    # ---- objective -------------------------------------------------------
    def t_total(
        self,
        gpu_counts: Sequence[int],
        pim_counts: Sequence[int],
        total_routed_tokens: int,
        cost_table=None,
    ) -> float:
        return max(
            self.t_comm(total_routed_tokens),
            self.t_gpu(gpu_counts),
            self.t_pim(pim_counts, cost_table),
        )


def attention_time_on_pim(
    system: SystemSpec, attn: AttnLayerSpec, batch: int, seq: int
) -> float:
    """Decode attention executed on PIM (GEMV-shaped, internal-bw bound)."""
    pim = system.pim
    if pim is None:
        raise ValueError("system has no PIM")
    t_stream = attn.kv_bytes(batch, seq) / pim.internal_bw
    # per-request score+value GEMV pair (commands per head-group batch)
    t_cmd = batch * 2 * pim.gemv_cmd_overhead
    return (t_stream + t_cmd) / (1.0 - pim.timing.refresh_overhead)


def attention_time_on_xpu(
    system: SystemSpec, attn: AttnLayerSpec, batch: int, seq: int
) -> float:
    """Decode attention kept on the xPU (external-HBM bound)."""
    xpu = system.xpu
    t_mem = attn.kv_bytes(batch, seq) / xpu.hbm_bw
    t_comp = attn.decode_flops(batch, seq) / xpu.peak_flops
    return max(t_mem, t_comp)
