"""Runtime PIM cost table (paper §5.1, "Timing Models").

The Sieve scheduler maintains a table keyed by token count whose values are
the observed PIM execution times for experts with that token count, updated
with an exponential moving average after each iteration.  For unobserved
token counts it falls back to a roofline estimate — known to be optimistic
by 1.8-4.2x because it ignores DRAM timing overheads (row-buffer conflicts,
bank contention, refresh).  The fallback is used at most once per key: the
first observation replaces it.

Storage is a dense ``count -> seconds`` float64 array (plus a dict spill
for pathological keys), so the batched queries the vectorized schedulers
issue are one fancy-index each:

* :meth:`CostTable.lookup` — scalar path, unchanged semantics;
* :meth:`CostTable.lookup_vec` — batched lookup, bit-identical per element;
* :meth:`CostTable.update_batch` — sequential-equivalent EMA absorb; one
  vectorized step when the batch's keys are distinct.
"""

from __future__ import annotations

from typing import Callable, Dict

import numpy as np

# Observed token counts are bounded by the per-step batch; keys beyond this
# spill to a dict so a pathological key cannot balloon the dense array.
_DENSE_CAP = 1 << 20


class CostTable:
    """EMA table: token count -> observed PIM execution time (seconds)."""

    def __init__(
        self,
        fallback: Callable[[int], float],
        alpha: float = 0.25,
        fallback_vec: Callable[[np.ndarray], np.ndarray] = None,
    ):
        if not (0.0 < alpha <= 1.0):
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self._fallback = fallback
        # Optional batched twin of ``fallback`` (must be bit-identical per
        # element); lets lookup_vec resolve all misses in one array op.
        self._fallback_vec = fallback_vec
        self.alpha = alpha
        self._dense = np.zeros(0, dtype=np.float64)
        self._dense_ok = np.zeros(0, dtype=bool)
        self._big: Dict[int, float] = {}  # keys >= _DENSE_CAP
        self.n_updates = 0
        self.n_fallback_lookups = 0
        # Non-finite observations (nan/inf) are silently skipped rather
        # than raised: they come from broken probes at runtime, and a
        # poisoned sample must never abort serving or poison the EMA.
        self.n_rejected = 0
        # Monotone content version: bumps on every mutation (update /
        # update_batch / load_state_dict), so exporters can skip re-export
        # when nothing changed since the last refresh.
        self.version = 0
        # Fallback values are deterministic per key; memoize so the batched
        # path pays for each unobserved count once.
        self._fallback_memo: Dict[int, float] = {}

    # -- queries -----------------------------------------------------------
    def _get(self, key: int):
        if 0 <= key < self._dense_ok.shape[0] and self._dense_ok[key]:
            return float(self._dense[key])
        return self._big.get(key)

    def lookup(self, n_tokens: int) -> float:
        t = self._get(int(n_tokens))
        if t is not None:
            return t
        self.n_fallback_lookups += 1
        return self._fallback(int(n_tokens))

    def lookup_vec(self, counts) -> np.ndarray:
        """Batched :meth:`lookup` over an int array of token counts.

        Returns float64 seconds per element, bit-identical to scalar
        ``lookup`` on each element.  ``n_fallback_lookups`` advances by the
        number of unobserved elements, mirroring the scalar accounting.
        """
        c = np.asarray(counts, dtype=np.int64)
        out = np.empty(c.shape, dtype=np.float64)
        n_dense = self._dense_ok.shape[0]
        in_range = (c >= 0) & (c < n_dense)
        hit = np.zeros(c.shape, dtype=bool)
        if n_dense:
            hit[in_range] = self._dense_ok[c[in_range]]
            out[hit] = self._dense[c[hit]]
        miss = ~hit
        n_miss = int(miss.sum())
        if n_miss:
            if (
                self._fallback_vec is not None
                and not self._big
                and c.min(initial=0) >= 0
                and c.max(initial=0) < _DENSE_CAP
            ):
                out[miss] = self._fallback_vec(c[miss])
                self.n_fallback_lookups += n_miss
            else:
                memo = self._fallback_memo
                vals = []
                for k in c[miss].tolist():
                    t = self._big.get(k)
                    if t is None:
                        t = memo.get(k)
                        if t is None:
                            t = float(self._fallback(k))
                            memo[k] = t
                        self.n_fallback_lookups += 1
                    vals.append(t)
                out[miss] = vals
        return out

    def has(self, n_tokens: int) -> bool:
        return self._get(int(n_tokens)) is not None

    @property
    def coverage(self) -> int:
        return int(self._dense_ok.sum()) + len(self._big)

    def observed(self) -> Dict[int, float]:
        out = {int(k): float(self._dense[k]) for k in np.nonzero(self._dense_ok)[0]}
        out.update(self._big)
        return out

    def export(self, max_count: int) -> np.ndarray:
        """Dense float32 ``count -> seconds`` array for the jit scheduler.

        Stable contract (the equivalence suite pins it): ``export(m)[c] ==
        float32(lookup(c))`` for every ``1 <= c <= m`` — observed entries
        verbatim, the fallback elsewhere — and ``export(m)[0] == 0.0``
        (a 0-token expert costs nothing; the schedulers mask inactive
        experts before indexing).  Keys outside ``[0, m]`` — including the
        negative/huge-key dict spill — cannot be represented in a dense
        count-indexed table and are simply not exported; the jit consumer
        clamps its index into range.  Spilled keys do not perturb the
        in-range values.
        """
        out = np.empty(max_count + 1, dtype=np.float64)
        out[0] = 0.0
        if max_count:
            counts = np.arange(1, max_count + 1, dtype=np.int64)
            out[1:] = self.lookup_vec(counts)
        return out.astype(np.float32)

    # -- updates -----------------------------------------------------------
    def _ensure_dense(self, key: int) -> None:
        if key >= self._dense_ok.shape[0]:
            new_len = max(2 * self._dense_ok.shape[0], key + 1, 64)
            dense = np.zeros(new_len, dtype=np.float64)
            ok = np.zeros(new_len, dtype=bool)
            dense[: self._dense.shape[0]] = self._dense
            ok[: self._dense_ok.shape[0]] = self._dense_ok
            self._dense, self._dense_ok = dense, ok

    def update(self, n_tokens: int, observed_time: float) -> float:
        """EMA update; returns the new table value.

        Negative finite times are a caller bug (raise); non-finite times
        are runtime measurement garbage (skip, count in ``n_rejected``,
        return the current value unchanged).
        """
        if not np.isfinite(observed_time):
            self.n_rejected += 1
            prev = self._get(int(n_tokens))
            return prev if prev is not None else self._fallback(int(n_tokens))
        if observed_time < 0:
            raise ValueError("observed_time must be non-negative")
        key = int(n_tokens)
        prev = self._get(key)
        if prev is None:
            new = float(observed_time)  # first observation replaces fallback
        else:
            new = (1.0 - self.alpha) * prev + self.alpha * float(observed_time)
        if 0 <= key < _DENSE_CAP:
            self._ensure_dense(key)
            self._dense[key] = new
            self._dense_ok[key] = True
        else:  # negative or pathologically large keys spill to the dict
            self._big[key] = new
        self.n_updates += 1
        self.version += 1
        return new

    def update_many(self, items) -> None:
        for n_tokens, t in items:
            self.update(n_tokens, t)

    def update_batch(self, counts, times, assume_unique: bool = False) -> None:
        """Sequential-equivalent batch of :meth:`update` calls.

        The per-key EMA recurrence is order-sensitive, so repeated keys are
        absorbed in the given order; when the batch's keys are distinct
        (the engine dedupes per-step observations — pass
        ``assume_unique=True`` to skip the re-check) the whole batch is one
        vectorized EMA step over the dense array.
        """
        c = np.asarray(counts, dtype=np.int64)
        t = np.asarray(times, dtype=np.float64)
        if c.shape != t.shape:
            raise ValueError("counts and times must have matching shapes")
        finite = np.isfinite(t)
        if not finite.all():
            # drop nan/inf samples (broken probes must not poison the EMA
            # — note ``t < 0`` is False for nan, so without this check a
            # nan would sail through the negative guard below)
            self.n_rejected += int((~finite).sum())
            c, t = c[finite], t[finite]
        if c.size and (t < 0).any():
            raise ValueError("observed_time must be non-negative")
        if (
            c.size
            and c.min(initial=0) >= 0
            and c.max(initial=0) < _DENSE_CAP
            and (assume_unique or np.unique(c).size == c.size)
        ):
            self._ensure_dense(int(c.max()))
            ok = self._dense_ok[c]
            prev = self._dense[c]
            new = np.where(ok, (1.0 - self.alpha) * prev + self.alpha * t, t)
            self._dense[c] = new
            self._dense_ok[c] = True
            self.n_updates += c.size
            self.version += 1
            return
        for key, obs in zip(c.tolist(), t.tolist()):
            self.update(key, obs)

    # -- persistence (used by the serving engine across restarts) -----------
    def state_dict(self) -> dict:
        return {"alpha": self.alpha, "table": self.observed()}

    def load_state_dict(self, state: dict) -> None:
        self.alpha = float(state["alpha"])
        self._dense = np.zeros(0, dtype=np.float64)
        self._dense_ok = np.zeros(0, dtype=bool)
        self._big = {}
        for k, v in state["table"].items():
            key, val = int(k), float(v)
            if 0 <= key < _DENSE_CAP:
                self._ensure_dense(key)
                self._dense[key] = val
                self._dense_ok[key] = True
            else:
                self._big[key] = val
        self.version += 1


def make_roofline_fallback(cost_model) -> Callable[[int], float]:
    """Roofline fallback bound to a CostModel (paper's one-time estimate)."""
    return cost_model.t_pim_gemv_roofline
