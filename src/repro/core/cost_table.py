"""Runtime PIM cost table (paper §5.1, "Timing Models").

The Sieve scheduler maintains a table keyed by token count whose values are
the observed PIM execution times for experts with that token count, updated
with an exponential moving average after each iteration.  For unobserved
token counts it falls back to a roofline estimate — known to be optimistic
by 1.8-4.2x because it ignores DRAM timing overheads (row-buffer conflicts,
bank contention, refresh).  The fallback is used at most once per key: the
first observation replaces it.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional


class CostTable:
    """EMA table: token count -> observed PIM execution time (seconds)."""

    def __init__(
        self,
        fallback: Callable[[int], float],
        alpha: float = 0.25,
    ):
        if not (0.0 < alpha <= 1.0):
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self._fallback = fallback
        self.alpha = alpha
        self._table: Dict[int, float] = {}
        self.n_updates = 0
        self.n_fallback_lookups = 0

    # -- queries -----------------------------------------------------------
    def lookup(self, n_tokens: int) -> float:
        t = self._table.get(int(n_tokens))
        if t is not None:
            return t
        self.n_fallback_lookups += 1
        return self._fallback(int(n_tokens))

    def has(self, n_tokens: int) -> bool:
        return int(n_tokens) in self._table

    @property
    def coverage(self) -> int:
        return len(self._table)

    def observed(self) -> Dict[int, float]:
        return dict(self._table)

    # -- updates -----------------------------------------------------------
    def update(self, n_tokens: int, observed_time: float) -> float:
        """EMA update; returns the new table value."""
        if observed_time < 0:
            raise ValueError("observed_time must be non-negative")
        key = int(n_tokens)
        prev = self._table.get(key)
        if prev is None:
            new = float(observed_time)  # first observation replaces fallback
        else:
            new = (1.0 - self.alpha) * prev + self.alpha * float(observed_time)
        self._table[key] = new
        self.n_updates += 1
        return new

    def update_many(self, items) -> None:
        for n_tokens, t in items:
            self.update(n_tokens, t)

    # -- persistence (used by the serving engine across restarts) -----------
    def state_dict(self) -> dict:
        return {"alpha": self.alpha, "table": dict(self._table)}

    def load_state_dict(self, state: dict) -> None:
        self.alpha = float(state["alpha"])
        self._table = {int(k): float(v) for k, v in state["table"].items()}


def make_roofline_fallback(cost_model) -> Callable[[int], float]:
    """Roofline fallback bound to a CostModel (paper's one-time estimate)."""
    return cost_model.t_pim_gemv_roofline
