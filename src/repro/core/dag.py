"""Fig-8 dependency graph for one MoE layer step (paper §6.1).

Nodes carry a resource ("gpu", "pim", "link", or None for zero-cost
synchronization points) and a duration.  The runtime overlap engine
(:mod:`repro.core.overlap`) list-schedules this DAG onto the per-device
resources; the simulator builds one instance per (device, layer) and chains
them.

Node naming follows the paper's circled numbering:

    1  attn_out          (pim or gpu, depending on policy)
    2  router            (gpu)
    3  allgather_maps    (link)
    4  metadata          (gpu)
    5d dispatch_a2a      (link)
    5s sieve_schedule    (gpu)     - the scheduler itself (~20us, §5.2)
    6w load_weights      (gpu hbm) - HBM-PIM -> GPU for experts in G
    6c pim_commands      (gpu)     - command generation for experts in S
    7g grouped_gemm      (gpu)
    7p pim_gemv          (pim)
    8  pim_readback      (gpu hbm)
    9  combine_a2a       (link)
    10 aggregate         (gpu)
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class Node:
    name: str
    resource: Optional[str]  # "gpu" | "pim" | "link" | None
    duration: float
    deps: Tuple[str, ...] = ()


@dataclass
class Dag:
    nodes: Dict[str, Node] = field(default_factory=dict)

    def add(self, name: str, resource: Optional[str], duration: float, deps=()):
        if name in self.nodes:
            raise ValueError(f"duplicate node {name}")
        for d in deps:
            if d not in self.nodes:
                raise ValueError(f"unknown dep {d} for {name}")
        self.nodes[name] = Node(name, resource, float(duration), tuple(deps))
        return name

    def topo_order(self) -> List[str]:
        order, seen, temp = [], set(), set()

        def visit(n: str):
            if n in seen:
                return
            if n in temp:
                raise ValueError(f"cycle at {n}")
            temp.add(n)
            for d in self.nodes[n].deps:
                visit(d)
            temp.discard(n)
            seen.add(n)
            order.append(n)

        for n in self.nodes:
            visit(n)
        return order

    def validate(self):
        self.topo_order()
        return self

    def compile(self):
        """Freeze this DAG's topology for repeated duration-array
        evaluation (see :class:`repro.core.overlap.CompiledDag`)."""
        from .overlap import CompiledDag

        return CompiledDag(self)


def merge_dags(dags: Dict[str, "Dag"]) -> "Dag":
    """Merge independent DAGs (e.g. interleaved half-batches, Fig 6a) into
    one graph so ``list_schedule`` resolves their resource contention."""
    out = Dag()
    for prefix, g in dags.items():
        for name in g.topo_order():
            n = g.nodes[name]
            out.add(
                f"{prefix}/{name}",
                n.resource,
                n.duration,
                deps=tuple(f"{prefix}/{d}" for d in n.deps),
            )
    return out


def build_moe_layer_dag(
    *,
    t_attn: float,
    attn_on_pim: bool,
    t_router: float,
    t_qkv_load: float = 0.0,
    t_prefill_attn: float = 0.0,
    t_allgather: float,
    t_metadata: float,
    t_dispatch: float,
    t_sieve: float,
    t_load_weights: float,
    t_pim_cmds: float,
    t_grouped_gemm: float,
    t_pim_gemv: float,
    t_pim_readback: float,
    t_combine: float,
    t_aggregate: float,
    t_shared_load: float = 0.0,
    t_shared_gemm: float = 0.0,
) -> Dag:
    """Instantiate Fig 8 with measured/estimated durations.

    Overlap structure (paper §6.1):
      - dispatch a2a (5d), the sieve scheduler (5s) and shared-expert weight
        loading run concurrently after the allgather;
      - GPU grouped GEMM (7g) needs weights loaded (6w) and dispatched
        tokens (5d);
      - PIM GEMV (7p) needs commands (6c) issued after the schedule (5s);
      - aggregation (10) needs both 7g and the PIM readback (8), plus the
        combine a2a (9).
    """
    g = Dag()
    router_deps = []
    if t_qkv_load > 0:
        g.add("qkv_load", "gpu_hbm", t_qkv_load)
        g.add("attn", "pim" if attn_on_pim else "gpu", t_attn, deps=("qkv_load",))
    else:
        g.add("attn", "pim" if attn_on_pim else "gpu", t_attn)
    router_deps.append("attn")
    if t_prefill_attn > 0:
        g.add(
            "prefill_attn",
            "gpu",
            t_prefill_attn,
            deps=("qkv_load",) if t_qkv_load > 0 else (),
        )
        router_deps.append("prefill_attn")
    g.add("router", "gpu", t_router, deps=tuple(router_deps))
    g.add("allgather_maps", "link", t_allgather, deps=("router",))
    g.add("metadata", "gpu", t_metadata, deps=("allgather_maps",))
    g.add("dispatch_a2a", "link", t_dispatch, deps=("metadata",))
    g.add("sieve", "gpu", t_sieve, deps=("allgather_maps",))
    # Shared experts receive every token: weight loads start right after (2)
    # (paper: "relaxing the dependency (2)->(5d)->(6w) for shared experts").
    has_shared = (t_shared_load + t_shared_gemm) > 0
    if has_shared:
        g.add("shared_weights", "gpu_hbm", t_shared_load, deps=("router",))
        g.add(
            "shared_gemm",
            "gpu",
            t_shared_gemm,
            deps=("shared_weights", "dispatch_a2a"),
        )
    g.add("load_weights", "gpu_hbm", t_load_weights, deps=("sieve",))
    g.add("pim_cmds", "gpu", t_pim_cmds, deps=("sieve",))
    g.add("grouped_gemm", "gpu", t_grouped_gemm, deps=("load_weights", "dispatch_a2a"))
    g.add("pim_gemv", "pim", t_pim_gemv, deps=("pim_cmds", "dispatch_a2a"))
    g.add("pim_readback", "gpu_hbm", t_pim_readback, deps=("pim_gemv",))
    combine_deps = ["grouped_gemm", "pim_readback"]
    if has_shared:
        combine_deps.append("shared_gemm")
    g.add("combine_a2a", "link", t_combine, deps=tuple(combine_deps))
    g.add("aggregate", "gpu", t_aggregate, deps=("combine_a2a",))
    return g.validate()
