"""The Sieve scheduler (paper §5) and the baseline policies (paper §7.1).

All policies take the runtime token-count vector over the activated experts
of one MoE layer on one device (+ its EP peers' routed tokens) and return a
:class:`Partition` assigning each activated expert to the GPU/xPU or to PIM.

Policies
--------
``sieve``          paper §5.2 greedy: sort by count desc, start all-on-PIM,
                   move the most popular expert to GPU while T_total strictly
                   decreases; stop at the first non-improvement.
``sieve_argmin``   beyond-paper refinement: T_total evaluated for *every*
                   prefix split of the sorted order, take the global argmin.
                   Never worse than the paper greedy (the greedy's result is
                   one of the evaluated prefixes); same O(E log E) cost.
``pimoe``          PIMoE (DAC'25) reproduction: channel-EP on PIM, moves the
                   most popular expert from the busiest PIM channel to the
                   GPU until T_GPU exceeds T_PIM.  Ignores both attention-
                   on-PIM time and inter-GPU communication (paper §5.2).
``noexp``          all experts on GPU, attention on PIM (NeuPIMs/PAISE).
``allexp``         all experts on PIM (PAPI/Stratum).
``gpu_only``       everything (incl. attention) on the GPU.
``dual_threshold`` the model layer's fixed rule (expert_exec="dual_path"):
                   head = experts with > tail_tokens rows, cost-blind.
``dual_cost``      the model layer's cost-driven rule
                   (expert_exec="dual_path_cost"): sieve prefix argmin
                   clamped to the dual-path feasibility window — the host
                   twin of scheduler_jax.dual_path_split_cost.

Hot path
--------
``sieve_schedule`` and ``pimoe_schedule`` are vectorized: T_Comm/T_GPU/T_PIM
are evaluated for *all* prefix splits of the sorted count vector at once via
cumulative sums (``CostModel.t_gpu_prefix`` / ``t_pim_suffix``), so both the
paper greedy and the argmin refinement cost one O(E log E) sort plus O(E)
scans — instead of O(E^2) cost-model calls.  The straightforward scalar
implementations are retained as ``sieve_schedule_reference`` /
``pimoe_schedule_reference``: they are the oracles the equivalence suite
(tests/test_sched_vectorized.py) holds the vectorized path bit-exactly to.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from .cost_model import CostModel, attention_time_on_xpu
from .cost_table import CostTable

POLICIES = (
    "sieve",
    "sieve_argmin",
    "pimoe",
    "pimoe_dynamic",
    "noexp",
    "allexp",
    "gpu_only",
    "dual_threshold",
    "dual_cost",
)


@dataclass
class Partition:
    """Result of a scheduling decision for one MoE layer on one device."""

    gpu_experts: np.ndarray  # expert ids assigned to the xPU (grouped GEMM)
    pim_experts: np.ndarray  # expert ids assigned to PIM (serialized GEMV)
    t_comm: float
    t_gpu: float
    t_pim: float
    iterations: int = 0
    policy: str = "sieve"
    meta: dict = field(default_factory=dict)

    @property
    def t_total(self) -> float:
        return max(self.t_comm, self.t_gpu, self.t_pim)

    def validate(self, n_active: int) -> None:
        s = set(self.gpu_experts.tolist())
        p = set(self.pim_experts.tolist())
        assert not (s & p), "expert assigned to both GPU and PIM"
        assert len(s) + len(p) == n_active, "partition does not cover E"


def _active(counts: np.ndarray):
    """Expert ids with >=1 token, sorted by token count descending.

    Ties broken by expert id for determinism (stable sort on -count).
    """
    counts = np.asarray(counts, dtype=np.int64)
    ids = np.nonzero(counts > 0)[0]
    order = np.argsort(-counts[ids], kind="stable")
    return ids[order], counts


def _prefix_times(counts, cost_model, cost_table):
    """Shared prefix-family evaluation for the sorted-prefix policies.

    One cumulative-sum pass: ``t_all[g] = max(t_comm, t_gpu(prefix g),
    t_pim(suffix g))`` for every split of the active experts sorted by
    count descending.  ``sieve_schedule`` selects over the full range;
    the dual-path rules clamp it to their feasibility window — keeping
    the evaluation here means the two families cannot drift apart.
    """
    ids, counts = _active(counts)
    t_comm = cost_model.t_comm(int(counts.sum()))
    sorted_counts = counts[ids]
    t_gpu_all = cost_model.t_gpu_prefix(sorted_counts)
    t_pim_all = cost_model.t_pim_suffix(sorted_counts, cost_table)
    t_all = np.maximum(np.maximum(t_gpu_all, t_pim_all), t_comm)
    return ids, sorted_counts, t_comm, t_gpu_all, t_pim_all, t_all


# ---------------------------------------------------------------------------
# Sieve (paper §5.2)
# ---------------------------------------------------------------------------


def sieve_schedule(
    counts: Sequence[int],
    cost_model: CostModel,
    cost_table: Optional[CostTable] = None,
    *,
    mode: str = "greedy",
) -> Partition:
    """Paper §5.2 greedy (``mode='greedy'``) or prefix-argmin refinement.

    ``counts`` is the global token count per expert hosted on this device
    (after the routing-map AllGather, §6.1 ③).

    Vectorized: the greedy only ever moves the current most-popular expert,
    so its reachable states are exactly the prefixes of the sorted order.
    T_total for every prefix split comes from two cumulative-sum scans
    (O(E) after the sort); the greedy is the first non-improvement in that
    array and the argmin is its global minimum.  Bit-identical to
    :func:`sieve_schedule_reference`.
    """
    if mode not in ("greedy", "argmin"):
        raise ValueError(f"unknown mode {mode!r}")
    ids, sorted_counts, t_comm, t_gpu_all, t_pim_all, t_all = _prefix_times(
        counts, cost_model, cost_table
    )
    n = len(ids)

    if mode == "greedy":
        # First split whose successor does not strictly improve: the scalar
        # greedy advances while t[g+1] < t[g] and stops at the first
        # non-improvement, having evaluated splits 0..g+1.
        nonimp = np.nonzero(t_all[1:] >= t_all[:-1])[0]
        g = int(nonimp[0]) if nonimp.size else n
        iters = g + 2 if g < n else n + 1
    else:
        g = int(np.argmin(t_all))  # first occurrence, like the scalar scan
        iters = n + 1

    part = Partition(
        gpu_experts=ids[:g].copy(),
        pim_experts=ids[g:].copy(),
        t_comm=t_comm,
        t_gpu=float(t_gpu_all[g]),
        t_pim=float(t_pim_all[g]),
        iterations=iters,
        policy="sieve" if mode == "greedy" else "sieve_argmin",
        meta={"split": g, "n_active": n},
    )
    # no validate() here: a prefix split of distinct active ids satisfies
    # the partition invariants by construction, and the O(E) set walk is
    # measurable on the hot path (the scalar reference still validates).
    return part


def sieve_schedule_reference(
    counts: Sequence[int],
    cost_model: CostModel,
    cost_table: Optional[CostTable] = None,
    *,
    mode: str = "greedy",
) -> Partition:
    """Scalar oracle for :func:`sieve_schedule` (O(E^2) cost-model calls).

    Retained for the equivalence suite; do not use on the hot path.  The
    per-split PIM sum runs least-popular-first (the reversed suffix) so its
    float association order matches the vectorized suffix scan exactly.
    """
    if mode not in ("greedy", "argmin"):
        raise ValueError(f"unknown mode {mode!r}")
    ids, counts = _active(counts)
    total_routed = int(counts.sum())
    t_comm = cost_model.t_comm(total_routed)

    sorted_counts = counts[ids]  # descending
    n = len(ids)

    # Evaluate T_total for prefix split g = number of experts moved to GPU
    # (the greedy only ever moves the current most-popular expert, so its
    # reachable states are exactly the prefixes of the sorted order).
    def eval_split(g: int):
        gpu_c = sorted_counts[:g]
        pim_c = sorted_counts[g:][::-1]  # least-popular-first summation
        t_gpu = cost_model.t_gpu(gpu_c)
        t_pim = cost_model.t_pim(pim_c, cost_table)
        return t_gpu, t_pim, max(t_comm, t_gpu, t_pim)

    if mode == "greedy":
        g = 0
        t_gpu, t_pim, best = eval_split(0)
        iters = 1
        while g < n:
            t_gpu2, t_pim2, t2 = eval_split(g + 1)
            iters += 1
            if t2 < best:
                g, best, t_gpu, t_pim = g + 1, t2, t_gpu2, t_pim2
            else:
                break  # first non-improvement stops the scan (paper §5.2)
    else:
        best, g, t_gpu, t_pim = np.inf, 0, 0.0, 0.0
        iters = n + 1
        for k in range(n + 1):
            t_gpu2, t_pim2, t2 = eval_split(k)
            if t2 < best:
                best, g, t_gpu, t_pim = t2, k, t_gpu2, t_pim2

    part = Partition(
        gpu_experts=ids[:g].copy(),
        pim_experts=ids[g:].copy(),
        t_comm=t_comm,
        t_gpu=t_gpu,
        t_pim=t_pim,
        iterations=iters,
        policy="sieve" if mode == "greedy" else "sieve_argmin",
        meta={"split": g, "n_active": n},
    )
    part.validate(n)
    return part


# ---------------------------------------------------------------------------
# Dual-path split rules (the model layer's head/tail partition, mirrored
# here so the simulator charges exactly the split the compiled step runs)
# ---------------------------------------------------------------------------


def _dual_feasible_window(sorted_counts, tail_tokens: int, max_head: int):
    """Feasible prefix-split range ``[lo, hi]`` of the dual-path executor.

    ``lo``: every expert with more than ``tail_tokens`` rows must be in the
    grouped-GEMM head (the tail slab executes at most ``tail_tokens`` rows
    per expert).  ``hi``: the head-compaction budget (``max_head <= 0``
    means no budget).  ``lo > hi`` happens only when the budget squeezes a
    popular expert off the grouped path — the budget wins and the overflow
    rows surface as drops in the model layer.
    """
    n = len(sorted_counts)
    lo = int(np.sum(sorted_counts > tail_tokens))
    hi = n if max_head <= 0 else min(n, int(max_head))
    return lo, hi


def dual_threshold_schedule(
    counts: Sequence[int],
    cost_model: CostModel,
    cost_table: Optional[CostTable] = None,
    *,
    tail_tokens: int = 1,
    max_head: int = 0,
) -> Partition:
    """The model layer's fixed-threshold rule (``expert_exec="dual_path"``).

    Head (GPU/grouped-GEMM side) = every expert with more than
    ``tail_tokens`` routed tokens, optionally capped at the ``max_head``
    most popular; tail (PIM/GEMV side) = the rest.  Cost-model-blind by
    construction — this is the baseline the cost-driven rule must beat.
    The reported times still come from the cost model so the simulator
    charges the threshold rule for its blind spots.
    """
    ids, counts = _active(counts)
    sorted_counts = counts[ids]
    lo, hi = _dual_feasible_window(sorted_counts, tail_tokens, max_head)
    g = min(lo, hi)  # threshold boundary, clamped by the head budget
    part = Partition(
        gpu_experts=ids[:g].copy(),
        pim_experts=ids[g:].copy(),
        t_comm=cost_model.t_comm(int(counts.sum())),
        t_gpu=cost_model.t_gpu(sorted_counts[:g]),
        t_pim=cost_model.t_pim(sorted_counts[g:][::-1], cost_table),
        policy="dual_threshold",
        meta={"split": g, "n_active": len(ids), "tail_tokens": tail_tokens},
    )
    # no validate(): a prefix split of distinct active ids satisfies the
    # partition invariants by construction (cf. sieve_schedule) and this
    # runs per layer-half on the simulator hot path
    return part


def dual_cost_schedule(
    counts: Sequence[int],
    cost_model: CostModel,
    cost_table: Optional[CostTable] = None,
    *,
    tail_tokens: int = 1,
    max_head: int = 0,
    mode: str = "argmin",
) -> Partition:
    """Cost-driven dual-path split (``expert_exec="dual_path_cost"``).

    Same prefix family and cumulative-sum evaluation as
    :func:`sieve_schedule`, with the evaluated range clamped to the
    dual-path executor's feasibility window (see
    :func:`_dual_feasible_window`) — the host NumPy twin of
    :func:`repro.core.scheduler_jax.dual_path_split_cost`, so cluster
    simulations charge exactly the split the compiled step executes.
    Bit-identical to :func:`dual_cost_schedule_reference`.
    """
    if mode not in ("greedy", "argmin"):
        raise ValueError(f"unknown mode {mode!r}")
    ids, sorted_counts, t_comm, t_gpu_all, t_pim_all, t_all = _prefix_times(
        counts, cost_model, cost_table
    )
    n = len(ids)
    lo, hi = _dual_feasible_window(sorted_counts, tail_tokens, max_head)

    if lo > hi:  # budget below the feasibility floor: the budget wins
        g = hi
    elif mode == "greedy":
        seg = t_all[lo : hi + 1]
        nonimp = np.nonzero(seg[1:] >= seg[:-1])[0]
        g = lo + (int(nonimp[0]) if nonimp.size else hi - lo)
    else:
        g = lo + int(np.argmin(t_all[lo : hi + 1]))  # first occurrence

    part = Partition(
        gpu_experts=ids[:g].copy(),
        pim_experts=ids[g:].copy(),
        t_comm=t_comm,
        t_gpu=float(t_gpu_all[g]),
        t_pim=float(t_pim_all[g]),
        policy="dual_cost",
        meta={
            "split": g,
            "n_active": n,
            "tail_tokens": tail_tokens,
            "window": (lo, hi),
        },
    )
    # prefix split of distinct active ids: partition invariants hold by
    # construction (cf. sieve_schedule)
    return part


def dual_cost_schedule_reference(
    counts: Sequence[int],
    cost_model: CostModel,
    cost_table: Optional[CostTable] = None,
    *,
    tail_tokens: int = 1,
    max_head: int = 0,
    mode: str = "argmin",
) -> Partition:
    """Scalar oracle for :func:`dual_cost_schedule` (O(E^2) eval calls)."""
    if mode not in ("greedy", "argmin"):
        raise ValueError(f"unknown mode {mode!r}")
    ids, counts = _active(counts)
    total_routed = int(counts.sum())
    t_comm = cost_model.t_comm(total_routed)
    sorted_counts = counts[ids]
    n = len(ids)
    lo, hi = _dual_feasible_window(sorted_counts, tail_tokens, max_head)

    def eval_split(g: int):
        gpu_c = sorted_counts[:g]
        pim_c = sorted_counts[g:][::-1]  # least-popular-first summation
        t_gpu = cost_model.t_gpu(gpu_c)
        t_pim = cost_model.t_pim(pim_c, cost_table)
        return t_gpu, t_pim, max(t_comm, t_gpu, t_pim)

    if lo > hi:
        g = hi
        t_gpu, t_pim, _ = eval_split(g)
    elif mode == "greedy":
        g = lo
        t_gpu, t_pim, best = eval_split(g)
        while g < hi:
            t_gpu2, t_pim2, t2 = eval_split(g + 1)
            if t2 < best:
                g, best, t_gpu, t_pim = g + 1, t2, t_gpu2, t_pim2
            else:
                break
    else:
        best, g, t_gpu, t_pim = np.inf, lo, 0.0, 0.0
        for k in range(lo, hi + 1):
            t_gpu2, t_pim2, t2 = eval_split(k)
            if t2 < best:
                best, g, t_gpu, t_pim = t2, k, t_gpu2, t_pim2

    part = Partition(
        gpu_experts=ids[:g].copy(),
        pim_experts=ids[g:].copy(),
        t_comm=t_comm,
        t_gpu=t_gpu,
        t_pim=t_pim,
        policy="dual_cost",
        meta={
            "split": g,
            "n_active": n,
            "tail_tokens": tail_tokens,
            "window": (lo, hi),
        },
    )
    part.validate(n)
    return part


# ---------------------------------------------------------------------------
# PIMoE baseline (paper §5.2 / §7.1)
# ---------------------------------------------------------------------------


def _pimoe_channel_assign(ids: np.ndarray, counts: np.ndarray, n_channels: int):
    """Greedy longest-processing-time assignment of experts to PIM channels
    (PIMoE uses channel-level expert parallelism, paper §6.2 / Fig 10)."""
    loads = np.zeros(n_channels)
    chan_of = {}
    for e in ids:  # ids already sorted by count desc
        c = int(np.argmin(loads))
        loads[c] += counts[e]
        chan_of[int(e)] = c
    return chan_of, loads


def pimoe_schedule(
    counts: Sequence[int],
    cost_model: CostModel,
    cost_table: Optional[CostTable] = None,
) -> Partition:
    """PIMoE: threshold-style offloading, blind to T_Comm and attention-on-PIM.

    Moves the most popular expert off the busiest channel while the PIM-side
    makespan (max channel load, *excluding* attention) exceeds the GPU time.

    Expert parallelism granularity: one expert per HBM-PIM *stack* (32
    pseudo-channels TP within the stack, EP across the 8 stacks).  Finer
    per-pseudo-channel EP would be uniformly dominated (256x slower weight
    streaming per expert); stack-level EP is the strongest reasonable
    reading of PIMoE's design and still exhibits the utilization imbalance
    of paper Fig 10.

    Vectorized: per-expert GEMV times are looked up in one batch and each
    iteration re-runs LPT + channel makespans as array ops; bit-identical
    to :func:`pimoe_schedule_reference`.
    """
    ids, counts = _active(counts)
    n = len(ids)
    pim = cost_model.system.pim
    n_channels = pim.stacks if pim is not None else 1

    sorted_counts = counts[ids]
    if cost_table is not None:
        gemv = cost_table.lookup_vec(sorted_counts) if n else np.zeros(0)
    else:
        gemv = (
            cost_model.t_pim_gemv_roofline_vec(sorted_counts)
            if n
            else np.zeros(0)
        )
    # stack-EP: an expert's GEMVs run on a single stack, which serves only
    # 1/n_stacks of the aggregate PIM bandwidth.
    gemv_ep = gemv * n_channels

    # Python-scalar loop state: the move loop is sequential by nature (each
    # LPT re-pack depends on the previous move), so the win comes from O(1)
    # incremental T_GPU (exact integer byte/FLOP accumulators mirroring
    # CostModel.t_gpu) and a single LPT pass per move that also records each
    # channel's first (most popular) expert.
    cnts = sorted_counts.tolist()
    times_ep = gemv_ep.tolist()
    tile = cost_model.system.xpu.tile_m
    m = cost_model.layer
    hbm_denom = cost_model.system.xpu.hbm_bw * cost_model.hbm_efficiency
    flop_denom = (
        cost_model.system.xpu.peak_flops * cost_model.grouped_gemm_efficiency
    )
    gpu_weight_bytes = 0  # n_live * expert_param_bytes
    gpu_tokens = 0
    gpu_padded = 0
    remaining = list(range(n))  # sorted-order indices still on PIM
    moved: List[int] = []  # sorted-order indices, in move order
    iters = 0
    while True:
        iters += 1
        # LPT over remaining token counts; track per-channel time load and
        # the first expert assigned to each channel (= its most popular).
        loads_cnt = [0.0] * n_channels
        loads_t = [0.0] * n_channels
        first_of = [-1] * n_channels
        for i in remaining:
            c = 0
            best = loads_cnt[0]
            for ch in range(1, n_channels):
                if loads_cnt[ch] < best:
                    best, c = loads_cnt[ch], ch
            loads_cnt[c] = best + cnts[i]
            loads_t[c] += times_ep[i]
            if first_of[c] < 0:
                first_of[c] = i
        t_pim = max(loads_t) if remaining else 0.0  # no attention term!
        # incremental T_GPU = max(offchip, comp), same arithmetic as
        # CostModel.t_gpu on the moved set (integer totals are exact)
        act_bytes = 2 * gpu_tokens * m.d_model * m.dtype_bytes
        t_offchip = (
            gpu_weight_bytes + act_bytes + cost_model.gpu_base_bytes
        ) / hbm_denom
        flops = 2.0 * gpu_padded * m.n_matrices * m.d_model * m.d_ff
        t_comp = (flops + cost_model.gpu_base_flops) / flop_denom
        t_gpu = t_offchip if t_offchip > t_comp else t_comp
        if t_pim <= t_gpu or not remaining:
            break
        # move the most popular expert from the busiest channel to the GPU
        busiest = loads_t.index(max(loads_t))
        mover = first_of[busiest]
        remaining.remove(mover)
        moved.append(mover)
        gpu_weight_bytes += m.expert_param_bytes
        gpu_tokens += cnts[mover]
        gpu_padded += -(-cnts[mover] // tile) * tile

    # Final ordering matches the scalar oracle: GPU experts stable-sorted by
    # count over their *move order* (count ties keep move order); PIM
    # experts keep the sorted order, which is already count-descending.
    moved_arr = np.asarray(moved, dtype=np.int64)
    gpu_order = moved_arr[np.argsort(-sorted_counts[moved_arr], kind="stable")]
    gpu_ids = ids[gpu_order]
    pim_ids = ids[np.asarray(remaining, dtype=np.int64)]
    total_routed = int(counts.sum())
    # Report the *actual* times (including the terms PIMoE ignored) so the
    # simulator charges PIMoE for its blind spots.
    t_pim_actual = cost_model.t_pim(counts[pim_ids], cost_table)
    part = Partition(
        gpu_experts=gpu_ids,
        pim_experts=pim_ids,
        t_comm=cost_model.t_comm(total_routed),
        t_gpu=cost_model.t_gpu(counts[gpu_ids]),
        t_pim=t_pim_actual,
        iterations=iters,
        policy="pimoe",
        meta={"n_active": n},
    )
    # validated by construction (disjoint move-set/remainder of active ids)
    return part


def pimoe_schedule_reference(
    counts: Sequence[int],
    cost_model: CostModel,
    cost_table: Optional[CostTable] = None,
) -> Partition:
    """Scalar oracle for :func:`pimoe_schedule` (per-expert dict walk)."""
    ids, counts = _active(counts)
    n = len(ids)
    pim = cost_model.system.pim
    n_channels = pim.stacks if pim is not None else 1

    def gemv_time(c):
        if cost_table is not None:
            return cost_table.lookup(int(c))
        return cost_model.t_pim_gemv_roofline(int(c))

    on_pim: List[int] = list(ids)
    on_gpu: List[int] = []
    iters = 0
    while True:
        iters += 1
        chan_of, _ = _pimoe_channel_assign(
            np.asarray(on_pim, dtype=np.int64), counts, n_channels
        )
        loads = np.zeros(n_channels)
        for e in on_pim:
            # stack-EP: an expert's GEMVs run on a single stack, which
            # serves only 1/n_stacks of the aggregate PIM bandwidth.
            loads[chan_of[int(e)]] += gemv_time(counts[e]) * n_channels
        t_pim = float(loads.max()) if on_pim else 0.0  # no attention term!
        t_gpu = cost_model.t_gpu(counts[np.asarray(on_gpu, dtype=np.int64)] if on_gpu else [])
        if t_pim <= t_gpu or not on_pim:
            break
        # move the most popular expert from the busiest channel to the GPU
        busiest = int(loads.argmax())
        cands = [e for e in on_pim if chan_of[int(e)] == busiest]
        mover = max(cands, key=lambda e: counts[e])
        on_pim.remove(mover)
        on_gpu.append(mover)

    gpu_ids = np.asarray(sorted(on_gpu, key=lambda e: -counts[e]), dtype=np.int64)
    pim_ids = np.asarray(sorted(on_pim, key=lambda e: -counts[e]), dtype=np.int64)
    total_routed = int(counts.sum())
    # Report the *actual* times (including the terms PIMoE ignored) so the
    # simulator charges PIMoE for its blind spots.
    t_pim_actual = cost_model.t_pim(counts[pim_ids], cost_table)
    part = Partition(
        gpu_experts=gpu_ids,
        pim_experts=pim_ids,
        t_comm=cost_model.t_comm(total_routed),
        t_gpu=cost_model.t_gpu(counts[gpu_ids]),
        t_pim=t_pim_actual,
        iterations=iters,
        policy="pimoe",
        meta={"n_active": n},
    )
    part.validate(n)
    return part


# ---------------------------------------------------------------------------
# Static baselines
# ---------------------------------------------------------------------------


def noexp_schedule(counts, cost_model, cost_table=None) -> Partition:
    """NoExp: attention on PIM, every expert on the GPU (NeuPIMs/PAISE)."""
    ids, counts = _active(counts)
    part = Partition(
        gpu_experts=ids.copy(),
        pim_experts=np.asarray([], dtype=np.int64),
        t_comm=cost_model.t_comm(int(counts.sum())),
        t_gpu=cost_model.t_gpu(counts[ids]),
        t_pim=cost_model.t_pim([], cost_table),  # attention only
        policy="noexp",
        meta={"n_active": len(ids)},
    )
    part.validate(len(ids))
    return part


def allexp_schedule(counts, cost_model, cost_table=None) -> Partition:
    """AllExp: every expert on PIM (PAPI / Stratum policy)."""
    ids, counts = _active(counts)
    part = Partition(
        gpu_experts=np.asarray([], dtype=np.int64),
        pim_experts=ids.copy(),
        t_comm=cost_model.t_comm(int(counts.sum())),
        t_gpu=cost_model.t_gpu([]),
        t_pim=cost_model.t_pim(counts[ids], cost_table),
        policy="allexp",
        meta={"n_active": len(ids)},
    )
    part.validate(len(ids))
    return part


def gpu_only_schedule(counts, cost_model, cost_table=None, attn_spec=None,
                      batch: int = 0, seq: int = 0) -> Partition:
    """GPU-Only: no PIM at all; attention also runs on the xPU."""
    ids, counts = _active(counts)
    t_attn_gpu = 0.0
    if attn_spec is not None and batch and seq:
        t_attn_gpu = attention_time_on_xpu(cost_model.system, attn_spec, batch, seq)
    t_gpu = max(
        cost_model.t_gpu_offchip(counts[ids]) + t_attn_gpu,
        cost_model.t_gpu_comp(counts[ids]) + t_attn_gpu,
    )
    part = Partition(
        gpu_experts=ids.copy(),
        pim_experts=np.asarray([], dtype=np.int64),
        t_comm=cost_model.t_comm(int(counts.sum())),
        t_gpu=t_gpu,
        t_pim=0.0,
        policy="gpu_only",
        meta={"n_active": len(ids)},
    )
    part.validate(len(ids))
    return part


def pimoe_static_partition(
    counts: Sequence[int],
    static_pim_ids,
    cost_model: CostModel,
    cost_table: Optional[CostTable] = None,
) -> Partition:
    """Apply PIMoE's *static* placement at runtime (paper §5.2: "PIMoE uses
    a static threshold ...", §7.3: degrades when the runtime distribution
    shifts).  ``static_pim_ids`` is the expert-id set assigned to PIM during
    calibration (see :func:`pimoe_schedule`); at runtime each activated
    expert executes wherever its id was pinned, regardless of its current
    token count.  ``static_pim_ids`` may also be a precomputed boolean mask
    over expert ids (the runtime's O(1) pinning lookup).
    """
    ids, counts = _active(counts)
    if isinstance(static_pim_ids, np.ndarray) and static_pim_ids.dtype == np.bool_:
        mask = static_pim_ids[ids]
    else:
        static_arr = np.fromiter(
            (int(e) for e in static_pim_ids), dtype=np.int64
        )
        mask = np.isin(ids, static_arr)
    pim_ids = ids[mask]
    gpu_ids = ids[~mask]
    part = Partition(
        gpu_experts=gpu_ids,
        pim_experts=pim_ids,
        t_comm=cost_model.t_comm(int(counts.sum())),
        t_gpu=cost_model.t_gpu(counts[gpu_ids]),
        t_pim=cost_model.t_pim(counts[pim_ids], cost_table),
        policy="pimoe",
        meta={"n_active": len(ids), "static": True},
    )
    part.validate(len(ids))
    return part


def schedule(policy: str, counts, cost_model, cost_table=None, **kw) -> Partition:
    """Dispatch by policy name (see :data:`POLICIES`)."""
    if policy == "sieve":
        return sieve_schedule(counts, cost_model, cost_table, mode="greedy")
    if policy == "sieve_argmin":
        return sieve_schedule(counts, cost_model, cost_table, mode="argmin")
    if policy in ("pimoe", "pimoe_dynamic"):
        return pimoe_schedule(counts, cost_model, cost_table)
    if policy == "noexp":
        return noexp_schedule(counts, cost_model, cost_table)
    if policy == "allexp":
        return allexp_schedule(counts, cost_model, cost_table)
    if policy == "gpu_only":
        return gpu_only_schedule(counts, cost_model, cost_table, **kw)
    if policy == "dual_threshold":
        return dual_threshold_schedule(counts, cost_model, cost_table, **kw)
    if policy == "dual_cost":
        return dual_cost_schedule(counts, cost_model, cost_table, **kw)
    raise ValueError(f"unknown policy {policy!r}; expected one of {POLICIES}")


def brute_force_schedule(
    counts: Sequence[int],
    cost_model: CostModel,
    cost_table: Optional[CostTable] = None,
) -> Partition:
    """Exhaustive 2^|E| search (tests only; paper §5.2 notes infeasibility)."""
    ids, counts = _active(counts)
    n = len(ids)
    if n > 16:
        raise ValueError("brute force is for tests with small |E| only")
    total_routed = int(counts.sum())
    t_comm = cost_model.t_comm(total_routed)
    best, best_mask = np.inf, 0
    for mask in range(1 << n):
        gpu_ids = ids[[i for i in range(n) if mask >> i & 1]]
        pim_ids = ids[[i for i in range(n) if not mask >> i & 1]]
        t = max(
            t_comm,
            cost_model.t_gpu(counts[gpu_ids]),
            cost_model.t_pim(counts[pim_ids], cost_table),
        )
        if t < best:
            best, best_mask = t, mask
    gpu_ids = ids[[i for i in range(n) if best_mask >> i & 1]]
    pim_ids = ids[[i for i in range(n) if not best_mask >> i & 1]]
    part = Partition(
        gpu_experts=gpu_ids,
        pim_experts=pim_ids,
        t_comm=t_comm,
        t_gpu=cost_model.t_gpu(counts[gpu_ids]),
        t_pim=cost_model.t_pim(counts[pim_ids], cost_table),
        policy="brute_force",
        meta={"n_active": n},
    )
    part.validate(n)
    return part
