"""The Sieve scheduler (paper §5) and the baseline policies (paper §7.1).

All policies take the runtime token-count vector over the activated experts
of one MoE layer on one device (+ its EP peers' routed tokens) and return a
:class:`Partition` assigning each activated expert to the GPU/xPU or to PIM.

Policies
--------
``sieve``          paper §5.2 greedy: sort by count desc, start all-on-PIM,
                   move the most popular expert to GPU while T_total strictly
                   decreases; stop at the first non-improvement.
``sieve_argmin``   beyond-paper refinement: T_total evaluated for *every*
                   prefix split of the sorted order, take the global argmin.
                   Never worse than the paper greedy (the greedy's result is
                   one of the evaluated prefixes); same O(E log E) cost.
``pimoe``          PIMoE (DAC'25) reproduction: channel-EP on PIM, moves the
                   most popular expert from the busiest PIM channel to the
                   GPU until T_GPU exceeds T_PIM.  Ignores both attention-
                   on-PIM time and inter-GPU communication (paper §5.2).
``noexp``          all experts on GPU, attention on PIM (NeuPIMs/PAISE).
``allexp``         all experts on PIM (PAPI/Stratum).
``gpu_only``       everything (incl. attention) on the GPU.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from .cost_model import CostModel, attention_time_on_xpu
from .cost_table import CostTable

POLICIES = (
    "sieve",
    "sieve_argmin",
    "pimoe",
    "pimoe_dynamic",
    "noexp",
    "allexp",
    "gpu_only",
)


@dataclass
class Partition:
    """Result of a scheduling decision for one MoE layer on one device."""

    gpu_experts: np.ndarray  # expert ids assigned to the xPU (grouped GEMM)
    pim_experts: np.ndarray  # expert ids assigned to PIM (serialized GEMV)
    t_comm: float
    t_gpu: float
    t_pim: float
    iterations: int = 0
    policy: str = "sieve"
    meta: dict = field(default_factory=dict)

    @property
    def t_total(self) -> float:
        return max(self.t_comm, self.t_gpu, self.t_pim)

    def validate(self, n_active: int) -> None:
        s = set(self.gpu_experts.tolist())
        p = set(self.pim_experts.tolist())
        assert not (s & p), "expert assigned to both GPU and PIM"
        assert len(s) + len(p) == n_active, "partition does not cover E"


def _active(counts: np.ndarray):
    """Expert ids with >=1 token, sorted by token count descending.

    Ties broken by expert id for determinism (stable sort on -count).
    """
    counts = np.asarray(counts, dtype=np.int64)
    ids = np.nonzero(counts > 0)[0]
    order = np.argsort(-counts[ids], kind="stable")
    return ids[order], counts


# ---------------------------------------------------------------------------
# Sieve (paper §5.2)
# ---------------------------------------------------------------------------


def sieve_schedule(
    counts: Sequence[int],
    cost_model: CostModel,
    cost_table: Optional[CostTable] = None,
    *,
    mode: str = "greedy",
) -> Partition:
    """Paper §5.2 greedy (``mode='greedy'``) or prefix-argmin refinement.

    ``counts`` is the global token count per expert hosted on this device
    (after the routing-map AllGather, §6.1 ③).
    """
    if mode not in ("greedy", "argmin"):
        raise ValueError(f"unknown mode {mode!r}")
    ids, counts = _active(counts)
    total_routed = int(counts.sum())
    t_comm = cost_model.t_comm(total_routed)

    sorted_counts = counts[ids]  # descending
    n = len(ids)

    # Evaluate T_total for prefix split g = number of experts moved to GPU
    # (the greedy only ever moves the current most-popular expert, so its
    # reachable states are exactly the prefixes of the sorted order).
    def eval_split(g: int):
        gpu_c = sorted_counts[:g]
        pim_c = sorted_counts[g:]
        t_gpu = cost_model.t_gpu(gpu_c)
        t_pim = cost_model.t_pim(pim_c, cost_table)
        return t_gpu, t_pim, max(t_comm, t_gpu, t_pim)

    if mode == "greedy":
        g = 0
        t_gpu, t_pim, best = eval_split(0)
        iters = 1
        while g < n:
            t_gpu2, t_pim2, t2 = eval_split(g + 1)
            iters += 1
            if t2 < best:
                g, best, t_gpu, t_pim = g + 1, t2, t_gpu2, t_pim2
            else:
                break  # first non-improvement stops the scan (paper §5.2)
    else:
        best, g, t_gpu, t_pim = np.inf, 0, 0.0, 0.0
        iters = n + 1
        for k in range(n + 1):
            t_gpu2, t_pim2, t2 = eval_split(k)
            if t2 < best:
                best, g, t_gpu, t_pim = t2, k, t_gpu2, t_pim2

    part = Partition(
        gpu_experts=ids[:g].copy(),
        pim_experts=ids[g:].copy(),
        t_comm=t_comm,
        t_gpu=t_gpu,
        t_pim=t_pim,
        iterations=iters,
        policy="sieve" if mode == "greedy" else "sieve_argmin",
        meta={"split": g, "n_active": n},
    )
    part.validate(n)
    return part


# ---------------------------------------------------------------------------
# PIMoE baseline (paper §5.2 / §7.1)
# ---------------------------------------------------------------------------


def _pimoe_channel_assign(ids: np.ndarray, counts: np.ndarray, n_channels: int):
    """Greedy longest-processing-time assignment of experts to PIM channels
    (PIMoE uses channel-level expert parallelism, paper §6.2 / Fig 10)."""
    loads = np.zeros(n_channels)
    chan_of = {}
    for e in ids:  # ids already sorted by count desc
        c = int(np.argmin(loads))
        loads[c] += counts[e]
        chan_of[int(e)] = c
    return chan_of, loads


def pimoe_schedule(
    counts: Sequence[int],
    cost_model: CostModel,
    cost_table: Optional[CostTable] = None,
) -> Partition:
    """PIMoE: threshold-style offloading, blind to T_Comm and attention-on-PIM.

    Moves the most popular expert off the busiest channel while the PIM-side
    makespan (max channel load, *excluding* attention) exceeds the GPU time.

    Expert parallelism granularity: one expert per HBM-PIM *stack* (32
    pseudo-channels TP within the stack, EP across the 8 stacks).  Finer
    per-pseudo-channel EP would be uniformly dominated (256x slower weight
    streaming per expert); stack-level EP is the strongest reasonable
    reading of PIMoE's design and still exhibits the utilization imbalance
    of paper Fig 10.
    """
    ids, counts = _active(counts)
    n = len(ids)
    pim = cost_model.system.pim
    n_channels = pim.stacks if pim is not None else 1

    def gemv_time(c):
        if cost_table is not None:
            return cost_table.lookup(int(c))
        return cost_model.t_pim_gemv_roofline(int(c))

    on_pim: List[int] = list(ids)
    on_gpu: List[int] = []
    iters = 0
    while True:
        iters += 1
        chan_of, _ = _pimoe_channel_assign(
            np.asarray(on_pim, dtype=np.int64), counts, n_channels
        )
        loads = np.zeros(n_channels)
        for e in on_pim:
            # stack-EP: an expert's GEMVs run on a single stack, which
            # serves only 1/n_stacks of the aggregate PIM bandwidth.
            loads[chan_of[int(e)]] += gemv_time(counts[e]) * n_channels
        t_pim = float(loads.max()) if on_pim else 0.0  # no attention term!
        t_gpu = cost_model.t_gpu(counts[np.asarray(on_gpu, dtype=np.int64)] if on_gpu else [])
        if t_pim <= t_gpu or not on_pim:
            break
        # move the most popular expert from the busiest channel to the GPU
        busiest = int(loads.argmax())
        cands = [e for e in on_pim if chan_of[int(e)] == busiest]
        mover = max(cands, key=lambda e: counts[e])
        on_pim.remove(mover)
        on_gpu.append(mover)

    gpu_ids = np.asarray(sorted(on_gpu, key=lambda e: -counts[e]), dtype=np.int64)
    pim_ids = np.asarray(sorted(on_pim, key=lambda e: -counts[e]), dtype=np.int64)
    total_routed = int(counts.sum())
    # Report the *actual* times (including the terms PIMoE ignored) so the
    # simulator charges PIMoE for its blind spots.
    t_pim_actual = cost_model.t_pim(counts[pim_ids], cost_table)
    part = Partition(
        gpu_experts=gpu_ids,
        pim_experts=pim_ids,
        t_comm=cost_model.t_comm(total_routed),
        t_gpu=cost_model.t_gpu(counts[gpu_ids]),
        t_pim=t_pim_actual,
        iterations=iters,
        policy="pimoe",
        meta={"n_active": n},
    )
    part.validate(n)
    return part


# ---------------------------------------------------------------------------
# Static baselines
# ---------------------------------------------------------------------------


def noexp_schedule(counts, cost_model, cost_table=None) -> Partition:
    """NoExp: attention on PIM, every expert on the GPU (NeuPIMs/PAISE)."""
    ids, counts = _active(counts)
    part = Partition(
        gpu_experts=ids.copy(),
        pim_experts=np.asarray([], dtype=np.int64),
        t_comm=cost_model.t_comm(int(counts.sum())),
        t_gpu=cost_model.t_gpu(counts[ids]),
        t_pim=cost_model.t_pim([], cost_table),  # attention only
        policy="noexp",
        meta={"n_active": len(ids)},
    )
    part.validate(len(ids))
    return part


def allexp_schedule(counts, cost_model, cost_table=None) -> Partition:
    """AllExp: every expert on PIM (PAPI / Stratum policy)."""
    ids, counts = _active(counts)
    part = Partition(
        gpu_experts=np.asarray([], dtype=np.int64),
        pim_experts=ids.copy(),
        t_comm=cost_model.t_comm(int(counts.sum())),
        t_gpu=cost_model.t_gpu([]),
        t_pim=cost_model.t_pim(counts[ids], cost_table),
        policy="allexp",
        meta={"n_active": len(ids)},
    )
    part.validate(len(ids))
    return part


def gpu_only_schedule(counts, cost_model, cost_table=None, attn_spec=None,
                      batch: int = 0, seq: int = 0) -> Partition:
    """GPU-Only: no PIM at all; attention also runs on the xPU."""
    ids, counts = _active(counts)
    t_attn_gpu = 0.0
    if attn_spec is not None and batch and seq:
        t_attn_gpu = attention_time_on_xpu(cost_model.system, attn_spec, batch, seq)
    t_gpu = max(
        cost_model.t_gpu_offchip(counts[ids]) + t_attn_gpu,
        cost_model.t_gpu_comp(counts[ids]) + t_attn_gpu,
    )
    part = Partition(
        gpu_experts=ids.copy(),
        pim_experts=np.asarray([], dtype=np.int64),
        t_comm=cost_model.t_comm(int(counts.sum())),
        t_gpu=t_gpu,
        t_pim=0.0,
        policy="gpu_only",
        meta={"n_active": len(ids)},
    )
    part.validate(len(ids))
    return part


def pimoe_static_partition(
    counts: Sequence[int],
    static_pim_ids,
    cost_model: CostModel,
    cost_table: Optional[CostTable] = None,
) -> Partition:
    """Apply PIMoE's *static* placement at runtime (paper §5.2: "PIMoE uses
    a static threshold ...", §7.3: degrades when the runtime distribution
    shifts).  ``static_pim_ids`` is the expert-id set assigned to PIM during
    calibration (see :func:`pimoe_schedule`); at runtime each activated
    expert executes wherever its id was pinned, regardless of its current
    token count.
    """
    ids, counts = _active(counts)
    static_pim_ids = set(int(e) for e in static_pim_ids)
    pim_ids = np.asarray([e for e in ids if int(e) in static_pim_ids], dtype=np.int64)
    gpu_ids = np.asarray([e for e in ids if int(e) not in static_pim_ids], dtype=np.int64)
    part = Partition(
        gpu_experts=gpu_ids,
        pim_experts=pim_ids,
        t_comm=cost_model.t_comm(int(counts.sum())),
        t_gpu=cost_model.t_gpu(counts[gpu_ids]),
        t_pim=cost_model.t_pim(counts[pim_ids], cost_table),
        policy="pimoe",
        meta={"n_active": len(ids), "static": True},
    )
    part.validate(len(ids))
    return part


def schedule(policy: str, counts, cost_model, cost_table=None, **kw) -> Partition:
    """Dispatch by policy name (see :data:`POLICIES`)."""
    if policy == "sieve":
        return sieve_schedule(counts, cost_model, cost_table, mode="greedy")
    if policy == "sieve_argmin":
        return sieve_schedule(counts, cost_model, cost_table, mode="argmin")
    if policy in ("pimoe", "pimoe_dynamic"):
        return pimoe_schedule(counts, cost_model, cost_table)
    if policy == "noexp":
        return noexp_schedule(counts, cost_model, cost_table)
    if policy == "allexp":
        return allexp_schedule(counts, cost_model, cost_table)
    if policy == "gpu_only":
        return gpu_only_schedule(counts, cost_model, cost_table, **kw)
    raise ValueError(f"unknown policy {policy!r}; expected one of {POLICIES}")


def brute_force_schedule(
    counts: Sequence[int],
    cost_model: CostModel,
    cost_table: Optional[CostTable] = None,
) -> Partition:
    """Exhaustive 2^|E| search (tests only; paper §5.2 notes infeasibility)."""
    ids, counts = _active(counts)
    n = len(ids)
    if n > 16:
        raise ValueError("brute force is for tests with small |E| only")
    total_routed = int(counts.sum())
    t_comm = cost_model.t_comm(total_routed)
    best, best_mask = np.inf, 0
    for mask in range(1 << n):
        gpu_ids = ids[[i for i in range(n) if mask >> i & 1]]
        pim_ids = ids[[i for i in range(n) if not mask >> i & 1]]
        t = max(
            t_comm,
            cost_model.t_gpu(counts[gpu_ids]),
            cost_model.t_pim(counts[pim_ids], cost_table),
        )
        if t < best:
            best, best_mask = t, mask
    gpu_ids = ids[[i for i in range(n) if best_mask >> i & 1]]
    pim_ids = ids[[i for i in range(n) if not best_mask >> i & 1]]
    part = Partition(
        gpu_experts=gpu_ids,
        pim_experts=pim_ids,
        t_comm=t_comm,
        t_gpu=cost_model.t_gpu(counts[gpu_ids]),
        t_pim=cost_model.t_pim(counts[pim_ids], cost_table),
        policy="brute_force",
        meta={"n_active": n},
    )
    part.validate(n)
    return part
