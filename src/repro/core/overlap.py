"""Event-driven list scheduler over per-device resources (paper §6.1).

Given a :class:`repro.core.dag.Dag`, schedule every node at the earliest
time permitted by (a) its dependencies and (b) its resource's availability.
Resources are serial executors ("gpu", "pim", "link", "gpu_hbm" — the
DMA/HBM channel used for weight loads and PIM readbacks, which overlaps
with "gpu" compute).  This models the overlap the Sieve runtime achieves:
GPU compute, PIM compute, and intra-/inter-device communication proceed
concurrently while cross-device dependencies are preserved.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .dag import Dag

DEFAULT_RESOURCES = ("gpu", "pim", "link", "gpu_hbm")


@dataclass
class ScheduledNode:
    name: str
    resource: Optional[str]
    start: float
    end: float


@dataclass
class Schedule:
    nodes: Dict[str, ScheduledNode] = field(default_factory=dict)

    @property
    def makespan(self) -> float:
        return max((n.end for n in self.nodes.values()), default=0.0)

    def busy_time(self, resource: str) -> float:
        return sum(
            n.end - n.start for n in self.nodes.values() if n.resource == resource
        )

    def utilization(self, resource: str) -> float:
        ms = self.makespan
        return self.busy_time(resource) / ms if ms > 0 else 0.0

    def critical_path(self, dag: Dag) -> List[str]:
        """Walk back from the last-finishing node through binding deps."""
        if not self.nodes:
            return []
        cur = max(self.nodes.values(), key=lambda n: n.end).name
        path = [cur]
        while True:
            node = dag.nodes[cur]
            binding = None
            for d in node.deps:
                if abs(self.nodes[d].end - self.nodes[cur].start) < 1e-15:
                    binding = d
                    break
            if binding is None:
                # resource wait: find the predecessor on the same resource
                cand = [
                    n
                    for n in self.nodes.values()
                    if n.resource == node.resource
                    and abs(n.end - self.nodes[cur].start) < 1e-15
                    and n.name != cur
                ]
                if not cand and node.deps:
                    binding = max(node.deps, key=lambda d: self.nodes[d].end)
                elif cand:
                    binding = cand[0].name
            if binding is None:
                break
            path.append(binding)
            cur = binding
        return list(reversed(path))


class CompiledDag:
    """Topology-frozen DAG for the duration-array fast path.

    ``list_schedule`` rebuilds dicts and dataclasses per call; the simulator
    evaluates the *same* layer topology thousands of times with different
    durations.  Compiling freezes the topo order, integer resource ids and
    integer dependency lists once, so each evaluation is a tight scan over
    plain floats.  :meth:`makespan` is bit-identical to
    ``list_schedule(dag).makespan`` for any duration assignment (same
    visit order, same float operations).
    """

    __slots__ = ("names", "slot", "resources", "_res", "_deps", "_n")

    def __init__(self, dag: Dag):
        order = dag.topo_order()
        self.names: Tuple[str, ...] = tuple(order)
        self.slot: Dict[str, int] = {n: i for i, n in enumerate(order)}
        res_names = list(DEFAULT_RESOURCES)
        for n in order:
            r = dag.nodes[n].resource
            if r is not None and r not in res_names:
                res_names.append(r)
        self.resources: Tuple[str, ...] = tuple(res_names)
        rid = {r: i for i, r in enumerate(res_names)}
        self._res = [
            rid[dag.nodes[n].resource] if dag.nodes[n].resource is not None else -1
            for n in order
        ]
        self._deps = [
            tuple(self.slot[d] for d in dag.nodes[n].deps) for n in order
        ]
        self._n = len(order)

    def makespan(self, durations) -> float:
        """Makespan only (the common case); no per-node records kept."""
        return self.evaluate(durations)[0]

    def evaluate(self, durations):
        """(makespan, per-resource busy seconds) for one duration vector.

        ``durations`` is indexed in compiled (topo) order — use
        :attr:`slot` to place named durations.
        """
        n_res = len(self.resources)
        avail = [0.0] * n_res
        busy = [0.0] * n_res
        ends = [0.0] * self._n
        makespan = 0.0
        for i in range(self._n):
            ready = 0.0
            for d in self._deps[i]:
                e = ends[d]
                if e > ready:
                    ready = e
            r = self._res[i]
            if r >= 0:
                a = avail[r]
                if a > ready:
                    ready = a
            dur = durations[i]
            end = ready + dur
            ends[i] = end
            if end > makespan:
                makespan = end
            if r >= 0:
                avail[r] = end
                busy[r] += dur
        return makespan, busy

    def utilizations(self, durations) -> Dict[str, float]:
        ms, busy = self.evaluate(durations)
        return {
            r: (busy[i] / ms if ms > 0 else 0.0)
            for i, r in enumerate(self.resources)
        }


def list_schedule(dag: Dag, start_times: Optional[Dict[str, float]] = None) -> Schedule:
    """Earliest-start list scheduling in topological order.

    ``start_times`` optionally carries per-resource availability from a
    previous layer/stage (for chaining layer DAGs into a model step).
    """
    avail: Dict[str, float] = dict(start_times or {})
    sched = Schedule()
    for name in dag.topo_order():
        node = dag.nodes[name]
        ready = max((sched.nodes[d].end for d in node.deps), default=0.0)
        if node.resource is not None:
            ready = max(ready, avail.get(node.resource, 0.0))
        end = ready + node.duration
        sched.nodes[name] = ScheduledNode(name, node.resource, ready, end)
        if node.resource is not None:
            avail[node.resource] = end
    return sched


def chain_layers(
    dags: List[Dag],
) -> Tuple[float, List[Schedule]]:
    """Schedule consecutive layer DAGs, carrying resource availability.

    Inter-layer dependency: layer i+1's first node cannot start before layer
    i's aggregate finishes (token stream dependency), but resources that
    freed up earlier may prefetch (weight loads) — modeled by carrying the
    per-resource availability map and a global data-ready floor.
    """
    t_floor = 0.0
    avail: Dict[str, float] = {}
    schedules = []
    for dag in dags:
        base = {r: max(t, t_floor) for r, t in avail.items()}
        for node in dag.nodes.values():
            if node.resource is not None and node.resource not in base:
                base[node.resource] = t_floor
        sched = list_schedule(dag, base)
        # shift: the DAG's entry nodes already respect t_floor via base
        schedules.append(sched)
        t_floor = sched.makespan
        for n in sched.nodes.values():
            if n.resource is not None:
                avail[n.resource] = max(avail.get(n.resource, 0.0), n.end)
    return t_floor, schedules
