"""jit-compatible Sieve scheduler (vectorized prefix formulation).

The paper's greedy only ever moves the currently most-popular expert from
PIM to the GPU, so every state it can reach is a *prefix* of the experts
sorted by token count (descending).  That makes the whole search expressible
as cumulative sums + one argmin — O(E log E), fully vectorized, and traceable
under ``jax.jit`` so the partition mask can be computed inside a compiled
serving step (no host round-trip on the critical path).

The PIM cost table enters as a dense array ``pim_time_by_count`` (seconds,
indexed by token count, clamped at the last entry) exported by
:class:`repro.core.cost_table.CostTable` between steps.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class SieveParams:
    """Static scalars of the cost model, precomputed on the host."""

    flops_per_row: float  # 2 * n_matrices * d_model * d_ff
    expert_param_bytes: float
    act_bytes_per_token: float  # 2 * d_model * dtype_bytes
    hbm_bw: float
    peak_flops_eff: float  # xpu.peak_flops * grouped_gemm_efficiency
    tile_m: int
    gpu_base_flops: float = 0.0
    gpu_base_bytes: float = 0.0
    pim_attn_time: float = 0.0
    t_comm: float = 0.0

    # field order of the packed array form (to_array / from_array); the
    # serving engine ships this as a device-resident float32 vector so a
    # cost-table refresh never changes the compiled step's signature.
    FIELDS = (
        "flops_per_row",
        "expert_param_bytes",
        "act_bytes_per_token",
        "hbm_bw",
        "peak_flops_eff",
        "tile_m",
        "gpu_base_flops",
        "gpu_base_bytes",
        "pim_attn_time",
        "t_comm",
    )

    @staticmethod
    def from_cost_model(cm, total_routed_tokens: int) -> "SieveParams":
        return SieveParams(
            flops_per_row=2.0 * cm.layer.n_matrices * cm.layer.d_model * cm.layer.d_ff,
            expert_param_bytes=float(cm.layer.expert_param_bytes),
            act_bytes_per_token=2.0 * cm.layer.d_model * cm.layer.dtype_bytes,
            hbm_bw=cm.system.xpu.hbm_bw * cm.hbm_efficiency,
            peak_flops_eff=cm.system.xpu.peak_flops * cm.grouped_gemm_efficiency,
            tile_m=cm.system.xpu.tile_m,
            gpu_base_flops=cm.gpu_base_flops,
            gpu_base_bytes=cm.gpu_base_bytes,
            pim_attn_time=cm.pim_attn_time,
            t_comm=cm.t_comm(total_routed_tokens),
        )

    def to_array(self) -> np.ndarray:
        """Pack into the float32 vector consumed by the dynamic scheduler."""
        return np.asarray(
            [float(getattr(self, f)) for f in self.FIELDS], dtype=np.float32
        )

    @staticmethod
    def from_array(arr) -> "SieveParams":
        vals = np.asarray(arr, dtype=np.float32)
        kw = {f: float(vals[i]) for i, f in enumerate(SieveParams.FIELDS)}
        kw["tile_m"] = int(kw["tile_m"])
        return SieveParams(**kw)


class SieveState(NamedTuple):
    """Device-resident cost-model state for the in-graph cost-driven split.

    Both leaves are plain arrays, so a :class:`SieveState` passes through
    ``jax.jit`` as a regular pytree input: the serving engine refreshes it
    on the EMA cost-table cadence without changing the compiled step.
    """

    pim_time_by_count: jax.Array  # (maxc+1,) float32 seconds per token count
    params: jax.Array  # (len(SieveParams.FIELDS),) float32 packed scalars


def make_sieve_state(cost_table, cost_model, max_count: int,
                     total_routed_tokens: int = 0) -> SieveState:
    """Host-side export: (CostTable, CostModel) -> a SieveState.

    The leaves are host numpy arrays (trace-safe: building a state inside
    a jit trace embeds them as constants).  Long-lived callers that pass
    the state into a compiled step every call (the serving engine) should
    ``jax.device_put`` it once per refresh to avoid re-uploading.
    """
    return SieveState(
        pim_time_by_count=export_cost_table(cost_table, cost_model, max_count),
        params=SieveParams.from_cost_model(
            cost_model, total_routed_tokens
        ).to_array(),
    )


def export_cost_table(cost_table, cost_model, max_count: int) -> np.ndarray:
    """Dense per-token-count PIM time array for the jit scheduler.

    Batched: one ``lookup_vec`` / roofline evaluation over the whole count
    range instead of ``max_count`` scalar lookups.  With a table this is
    exactly :meth:`repro.core.cost_table.CostTable.export` (the stable
    versioned contract the equivalence suite pins); without one it is the
    pure roofline export.
    """
    if cost_table is not None:
        return cost_table.export(max_count)
    out = np.empty(max_count + 1, dtype=np.float32)
    out[0] = 0.0
    counts = np.arange(1, max_count + 1, dtype=np.int64)
    out[1:] = cost_model.t_pim_gemv_roofline_vec(counts)
    return out


def _prefix_partition(
    counts: jax.Array,  # (E,) int32 token count per local expert
    pim_time_by_count: jax.Array,  # (maxc+1,) float32 seconds
    p: dict,  # SieveParams fields as python floats OR traced 0-d arrays
    mode: str,
    min_split=None,  # optional lower clamp on g (feasibility floor)
    max_split=None,  # optional upper clamp on g (head budget)
    weight_of_group=None,  # (E,) 0/1: does this entry charge weight bytes?
) -> dict:
    """Shared prefix-family evaluation behind the jit entry points.

    The cost-model scalars in ``p`` may be python floats (the static
    :func:`sieve_partition_jax` path, where they hash into the jit key) or
    traced 0-d float32 arrays unpacked from a :class:`SieveState` (the
    serving path, where a cost-table refresh must not retrace).  The
    arithmetic is float32 either way, so both paths pick the same split.

    ``min_split``/``max_split`` clamp the evaluated prefix family to
    ``[min_split, max_split]`` — the dual-path executor's execution-shape
    feasibility window (tail slab depth below, head budget above).  When
    the window is empty (budget below the feasibility floor) the budget
    wins and the squeezed rows surface as drops in the caller.

    ``weight_of_group`` (0/1 per entry) marks which entries charge their
    expert's ``expert_param_bytes`` in the T_GPU off-chip term.  The
    default charges every active entry — correct when entries are whole
    experts.  The EP a2a segmented layout passes the first-segment-of-
    each-expert indicator instead, so an expert whose segments all land
    in the head is charged its (shared) weights once, not once per
    source shard.
    """
    E = counts.shape[0]
    counts = counts.astype(jnp.int32)
    order = jnp.argsort(-counts, stable=True)  # popular first
    sc = counts[order]
    active = sc > 0
    n_active = jnp.sum(active)

    tile = jnp.asarray(p["tile_m"], jnp.int32)
    padded = jnp.where(active, ((sc + tile - 1) // tile) * tile, 0)
    # prefix over splits g = 0..E  (index i = "first i experts on GPU")
    cum_tokens = jnp.concatenate([jnp.zeros(1, sc.dtype), jnp.cumsum(sc)])
    cum_padded = jnp.concatenate([jnp.zeros(1, sc.dtype), jnp.cumsum(padded)])
    if weight_of_group is None:
        live = active.astype(jnp.int32)
    else:
        live = jnp.where(
            active, weight_of_group[order].astype(jnp.int32), 0
        )
    cum_live = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(live)])

    t_gpu_comp = (
        p["flops_per_row"] * cum_padded.astype(jnp.float32) + p["gpu_base_flops"]
    ) / p["peak_flops_eff"]
    t_gpu_mem = (
        p["expert_param_bytes"] * cum_live.astype(jnp.float32)
        + p["act_bytes_per_token"] * cum_tokens.astype(jnp.float32)
        + p["gpu_base_bytes"]
    ) / p["hbm_bw"]
    t_gpu = jnp.maximum(t_gpu_comp, t_gpu_mem)

    maxc = pim_time_by_count.shape[0] - 1
    per_expert_pim = pim_time_by_count[jnp.clip(sc, 0, maxc)]
    per_expert_pim = jnp.where(active, per_expert_pim, 0.0)
    cum_pim = jnp.concatenate([jnp.zeros(1, jnp.float32), jnp.cumsum(per_expert_pim)])
    t_pim = p["pim_attn_time"] + (cum_pim[-1] - cum_pim)

    t_total = jnp.maximum(jnp.maximum(t_gpu, t_pim), p["t_comm"])
    # splits beyond the active prefix are duplicates of g = n_active
    g_range = jnp.arange(E + 1)
    valid = g_range <= n_active
    lo = jnp.zeros((), jnp.int32) if min_split is None else min_split
    hi = n_active if max_split is None else jnp.minimum(n_active, max_split)
    valid = valid & (g_range >= lo) & (g_range <= hi)
    t_masked = jnp.where(valid, t_total, jnp.inf)
    if mode == "greedy":
        # first split whose successor does not strictly improve (paper
        # §5.2), scanning only inside the feasible window
        nonimp = (t_masked[1:] >= t_masked[:-1]) & valid[1:]
        g_star = jnp.where(jnp.any(nonimp), jnp.argmax(nonimp), hi)
    else:
        g_star = jnp.argmin(t_masked)
    # empty window (budget below the feasibility floor): the budget wins
    g_star = jnp.where(jnp.any(valid), g_star, hi).astype(jnp.int32)

    rank = jnp.argsort(order, stable=True)  # expert id -> popularity rank
    gpu_mask = (rank < g_star) & (counts > 0)
    return {
        "gpu_mask": gpu_mask,
        "order": order,
        "rank": rank,
        "split": g_star,
        "t_total": t_total[g_star],
        "t_gpu": t_gpu[g_star],
        "t_pim": t_pim[g_star],
        "t_comm": jnp.asarray(p["t_comm"], jnp.float32),
        "n_active": n_active,
    }


def _params_dict(params: SieveParams) -> dict:
    # pre-round to float32 so the static path is bit-identical to the
    # dynamic (packed-array) path, which stores float32 scalars
    return {f: np.float32(getattr(params, f)) for f in SieveParams.FIELDS}


def _params_dict_dynamic(params_arr: jax.Array) -> dict:
    arr = params_arr.astype(jnp.float32)
    return {f: arr[i] for i, f in enumerate(SieveParams.FIELDS)}


@partial(jax.jit, static_argnames=("params", "mode"))
def sieve_partition_jax(
    counts: jax.Array,  # (E,) int32 token count per local expert
    pim_time_by_count: jax.Array,  # (maxc+1,) float32 seconds
    params: SieveParams,
    mode: str = "argmin",
) -> dict:
    """Returns ``gpu_mask`` (E,) bool plus the evaluated split diagnostics.

    ``mode='argmin'`` is equivalent to ``scheduler.sieve_schedule(...,
    mode='argmin')`` — the global argmin over the prefix family (the
    beyond-paper refinement).  ``mode='greedy'`` reproduces the paper's
    first-non-improvement stop on the same prefix arrays — the host
    NumPy scheduler and this jit twin share the cumulative-sum
    formulation, so both cost one sort + O(E) scans.
    """
    return _prefix_partition(counts, pim_time_by_count, _params_dict(params), mode)


@partial(jax.jit, static_argnames=("mode",))
def sieve_partition_dynamic(
    counts: jax.Array,  # (E,) int32 token count per local expert
    pim_time_by_count: jax.Array,  # (maxc+1,) float32 seconds
    params_arr: jax.Array,  # (len(SieveParams.FIELDS),) float32 packed
    mode: str = "argmin",
) -> dict:
    """:func:`sieve_partition_jax` with the cost scalars as a *traced* array.

    This is the serving-engine form: ``params_arr`` (and the table) come
    from a :class:`SieveState` refreshed on the EMA cadence, so new cost
    observations change the split without recompiling the decode step.
    """
    return _prefix_partition(
        counts, pim_time_by_count, _params_dict_dynamic(params_arr), mode
    )


@partial(jax.jit, static_argnames=("tail_tokens", "max_head"))
def dual_path_split(
    rows: jax.Array,  # (E,) int32 buffered rows per local expert
    tail_tokens: int = 1,
    max_head: int | None = None,
) -> dict:
    """Head/tail partition for the in-graph dual-path MoE executor.

    Same prefix family as :func:`sieve_partition_jax` — the head is always
    a prefix of the experts sorted by row count (descending) — but with the
    split pinned by execution-shape constraints rather than the cost model:
    a tail expert must fit the static ``tail_tokens``-row GEMV slab, so the
    prefix boundary is the first expert with ``rows <= tail_tokens``.

    ``max_head`` (static) additionally caps the head at the ``max_head``
    most popular experts (the grouped path's compaction budget).  Rows of
    experts squeezed out of the capped head beyond their first
    ``tail_tokens`` rows cannot execute on either path and are reported in
    ``n_dropped`` (the caller charges them like capacity overflow).

    Fully vectorized and traceable under ``jit`` — counts-driven, no host
    sync on the decode critical path.
    """
    E = rows.shape[0]
    rows = rows.astype(jnp.int32)
    order = jnp.argsort(-rows, stable=True)  # popular first
    rank = jnp.argsort(order, stable=True)  # expert id -> popularity rank
    head = rows > tail_tokens
    if max_head is not None and max_head < E:
        head = head & (rank < max_head)
    tail = (rows > 0) & ~head
    # rows that fit neither path: beyond the head budget and past the tail
    # slab depth
    overflow = jnp.where((rows > tail_tokens) & ~head, rows - tail_tokens, 0)
    return {
        "head_mask": head,
        "tail_mask": tail,
        "order": order,
        "rank": rank,
        "n_head": jnp.sum(head.astype(jnp.int32)),
        "n_tail": jnp.sum(tail.astype(jnp.int32)),
        "n_dropped": jnp.sum(overflow).astype(jnp.int32),
    }


@partial(jax.jit, static_argnames=("tail_tokens", "max_head", "mode"))
def dual_path_split_cost(
    rows: jax.Array,  # (E,) int32 buffered rows per local expert
    pim_time_by_count: jax.Array,  # (maxc+1,) float32 seconds
    params_arr: jax.Array,  # packed SieveParams (SieveState.params)
    tail_tokens: int = 1,
    max_head: int | None = None,
    mode: str = "argmin",
    weight_of_group: jax.Array | None = None,  # (E,) 0/1 weight-byte mask
) -> dict:
    """Cost-driven head/tail partition (``expert_exec="dual_path_cost"``).

    Same output contract as :func:`dual_path_split`, but the prefix
    boundary comes from the learned cost model (:func:`sieve_partition_jax`
    arithmetic over the engine-exported table) instead of the fixed
    ``rows > tail_tokens`` threshold.  The evaluated prefix family is
    clamped to the execution-shape feasibility window:

    * **floor** — every expert with more than ``tail_tokens`` rows must be
      in the head (a tail expert only executes its first ``tail_tokens``
      rows), so the cost model chooses how many *additional* few-token
      experts ride the grouped-GEMM path instead of streaming GEMVs — the
      per-step decision the paper's learned table exists for;
    * **ceiling** — ``max_head`` (the grouped path's compaction budget).
      When the budget squeezes a ``>tail_tokens``-row expert off the
      grouped path its overflow rows are reported in ``n_dropped``,
      exactly like :func:`dual_path_split`.  NOTE: ``max_head`` follows
      :func:`dual_path_split`'s convention — ``None`` disables the budget
      and ``0`` is a zero-size head.  This differs from
      ``MoEConfig.dual_max_head``, where ``0`` means "no budget"; the
      model layer (``models.moe``) translates between the two.

    Cost scalars and table are *traced* inputs (a :class:`SieveState`), so
    the serving engine's refresh cadence never recompiles the decode step.
    """
    E = rows.shape[0]
    rows = rows.astype(jnp.int32)
    n_over = jnp.sum(rows > tail_tokens).astype(jnp.int32)
    cap = None if (max_head is None or max_head >= E) else jnp.asarray(
        max_head, jnp.int32
    )
    part = _prefix_partition(
        rows,
        pim_time_by_count,
        _params_dict_dynamic(params_arr),
        mode,
        min_split=n_over,
        max_split=cap,
        weight_of_group=weight_of_group,
    )
    head = part["gpu_mask"]
    tail = (rows > 0) & ~head
    overflow = jnp.where((rows > tail_tokens) & tail, rows - tail_tokens, 0)
    return {
        "head_mask": head,
        "tail_mask": tail,
        "order": part["order"],
        "rank": part["rank"],
        "split": part["split"],
        "t_total": part["t_total"],
        "t_gpu": part["t_gpu"],
        "t_pim": part["t_pim"],
        "n_head": jnp.sum(head.astype(jnp.int32)),
        "n_tail": jnp.sum(tail.astype(jnp.int32)),
        "n_dropped": jnp.sum(overflow).astype(jnp.int32),
    }
