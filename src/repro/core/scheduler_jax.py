"""jit-compatible Sieve scheduler (vectorized prefix formulation).

The paper's greedy only ever moves the currently most-popular expert from
PIM to the GPU, so every state it can reach is a *prefix* of the experts
sorted by token count (descending).  That makes the whole search expressible
as cumulative sums + one argmin — O(E log E), fully vectorized, and traceable
under ``jax.jit`` so the partition mask can be computed inside a compiled
serving step (no host round-trip on the critical path).

The PIM cost table enters as a dense array ``pim_time_by_count`` (seconds,
indexed by token count, clamped at the last entry) exported by
:class:`repro.core.cost_table.CostTable` between steps.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class SieveParams:
    """Static scalars of the cost model, precomputed on the host."""

    flops_per_row: float  # 2 * n_matrices * d_model * d_ff
    expert_param_bytes: float
    act_bytes_per_token: float  # 2 * d_model * dtype_bytes
    hbm_bw: float
    peak_flops_eff: float  # xpu.peak_flops * grouped_gemm_efficiency
    tile_m: int
    gpu_base_flops: float = 0.0
    gpu_base_bytes: float = 0.0
    pim_attn_time: float = 0.0
    t_comm: float = 0.0

    @staticmethod
    def from_cost_model(cm, total_routed_tokens: int) -> "SieveParams":
        return SieveParams(
            flops_per_row=2.0 * cm.layer.n_matrices * cm.layer.d_model * cm.layer.d_ff,
            expert_param_bytes=float(cm.layer.expert_param_bytes),
            act_bytes_per_token=2.0 * cm.layer.d_model * cm.layer.dtype_bytes,
            hbm_bw=cm.system.xpu.hbm_bw * cm.hbm_efficiency,
            peak_flops_eff=cm.system.xpu.peak_flops * cm.grouped_gemm_efficiency,
            tile_m=cm.system.xpu.tile_m,
            gpu_base_flops=cm.gpu_base_flops,
            gpu_base_bytes=cm.gpu_base_bytes,
            pim_attn_time=cm.pim_attn_time,
            t_comm=cm.t_comm(total_routed_tokens),
        )


def export_cost_table(cost_table, cost_model, max_count: int) -> np.ndarray:
    """Dense per-token-count PIM time array for the jit scheduler.

    Batched: one ``lookup_vec`` / roofline evaluation over the whole count
    range instead of ``max_count`` scalar lookups.
    """
    out = np.empty(max_count + 1, dtype=np.float32)
    out[0] = 0.0
    counts = np.arange(1, max_count + 1, dtype=np.int64)
    if cost_table is not None:
        out[1:] = cost_table.lookup_vec(counts)
    else:
        out[1:] = cost_model.t_pim_gemv_roofline_vec(counts)
    return out


@partial(jax.jit, static_argnames=("params", "mode"))
def sieve_partition_jax(
    counts: jax.Array,  # (E,) int32 token count per local expert
    pim_time_by_count: jax.Array,  # (maxc+1,) float32 seconds
    params: SieveParams,
    mode: str = "argmin",
) -> dict:
    """Returns ``gpu_mask`` (E,) bool plus the evaluated split diagnostics.

    ``mode='argmin'`` is equivalent to ``scheduler.sieve_schedule(...,
    mode='argmin')`` — the global argmin over the prefix family (the
    beyond-paper refinement).  ``mode='greedy'`` reproduces the paper's
    first-non-improvement stop on the same prefix arrays — the host
    NumPy scheduler and this jit twin share the cumulative-sum
    formulation, so both cost one sort + O(E) scans.
    """
    E = counts.shape[0]
    counts = counts.astype(jnp.int32)
    order = jnp.argsort(-counts, stable=True)  # popular first
    sc = counts[order]
    active = sc > 0
    n_active = jnp.sum(active)

    tile = params.tile_m
    padded = jnp.where(active, ((sc + tile - 1) // tile) * tile, 0)
    # prefix over splits g = 0..E  (index i = "first i experts on GPU")
    cum_tokens = jnp.concatenate([jnp.zeros(1, sc.dtype), jnp.cumsum(sc)])
    cum_padded = jnp.concatenate([jnp.zeros(1, sc.dtype), jnp.cumsum(padded)])
    cum_live = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(active.astype(jnp.int32))]
    )

    t_gpu_comp = (
        params.flops_per_row * cum_padded.astype(jnp.float32) + params.gpu_base_flops
    ) / params.peak_flops_eff
    t_gpu_mem = (
        params.expert_param_bytes * cum_live.astype(jnp.float32)
        + params.act_bytes_per_token * cum_tokens.astype(jnp.float32)
        + params.gpu_base_bytes
    ) / params.hbm_bw
    t_gpu = jnp.maximum(t_gpu_comp, t_gpu_mem)

    maxc = pim_time_by_count.shape[0] - 1
    per_expert_pim = pim_time_by_count[jnp.clip(sc, 0, maxc)]
    per_expert_pim = jnp.where(active, per_expert_pim, 0.0)
    cum_pim = jnp.concatenate([jnp.zeros(1, jnp.float32), jnp.cumsum(per_expert_pim)])
    t_pim = params.pim_attn_time + (cum_pim[-1] - cum_pim)

    t_total = jnp.maximum(jnp.maximum(t_gpu, t_pim), params.t_comm)
    # splits beyond the active prefix are duplicates of g = n_active
    valid = jnp.arange(E + 1) <= n_active
    t_total = jnp.where(valid, t_total, jnp.inf)
    if mode == "greedy":
        # first split whose successor does not strictly improve (paper §5.2)
        nonimp = (t_total[1:] >= t_total[:-1]) & valid[1:]
        g_star = jnp.where(jnp.any(nonimp), jnp.argmax(nonimp), n_active)
    else:
        g_star = jnp.argmin(t_total)

    rank = jnp.argsort(order, stable=True)  # expert id -> popularity rank
    gpu_mask = (rank < g_star) & (counts > 0)
    return {
        "gpu_mask": gpu_mask,
        "split": g_star,
        "t_total": t_total[g_star],
        "t_gpu": t_gpu[g_star],
        "t_pim": t_pim[g_star],
        "t_comm": jnp.asarray(params.t_comm, jnp.float32),
        "n_active": n_active,
    }


@partial(jax.jit, static_argnames=("tail_tokens", "max_head"))
def dual_path_split(
    rows: jax.Array,  # (E,) int32 buffered rows per local expert
    tail_tokens: int = 1,
    max_head: int | None = None,
) -> dict:
    """Head/tail partition for the in-graph dual-path MoE executor.

    Same prefix family as :func:`sieve_partition_jax` — the head is always
    a prefix of the experts sorted by row count (descending) — but with the
    split pinned by execution-shape constraints rather than the cost model:
    a tail expert must fit the static ``tail_tokens``-row GEMV slab, so the
    prefix boundary is the first expert with ``rows <= tail_tokens``.

    ``max_head`` (static) additionally caps the head at the ``max_head``
    most popular experts (the grouped path's compaction budget).  Rows of
    experts squeezed out of the capped head beyond their first
    ``tail_tokens`` rows cannot execute on either path and are reported in
    ``n_dropped`` (the caller charges them like capacity overflow).

    Fully vectorized and traceable under ``jit`` — counts-driven, no host
    sync on the decode critical path.
    """
    E = rows.shape[0]
    rows = rows.astype(jnp.int32)
    order = jnp.argsort(-rows, stable=True)  # popular first
    rank = jnp.argsort(order, stable=True)  # expert id -> popularity rank
    head = rows > tail_tokens
    if max_head is not None and max_head < E:
        head = head & (rank < max_head)
    tail = (rows > 0) & ~head
    # rows that fit neither path: beyond the head budget and past the tail
    # slab depth
    overflow = jnp.where((rows > tail_tokens) & ~head, rows - tail_tokens, 0)
    return {
        "head_mask": head,
        "tail_mask": tail,
        "order": order,
        "rank": rank,
        "n_head": jnp.sum(head.astype(jnp.int32)),
        "n_tail": jnp.sum(tail.astype(jnp.int32)),
        "n_dropped": jnp.sum(overflow).astype(jnp.int32),
    }
